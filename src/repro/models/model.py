"""LM façade: schema, init, loss (chunked CE), prefill, decode, cache specs."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import soft_cap
from repro.models.schema import ParamSpec, init_params
from repro.models.transformer import (depth_plan, encdec_forward, lm_forward,
                                      lm_schema)
from repro.parallel.context import constrain

_NEG = -1e30


def schema(cfg: ModelConfig) -> Dict[str, Any]:
    return lm_schema(cfg)


def init(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    return init_params(schema(cfg), key)


# ---------------------------------------------------------------------------
# loss: chunked cross-entropy (never materialises (B,S,V))
# ---------------------------------------------------------------------------

def chunked_ce(cfg: ModelConfig, embed_params, hidden: jnp.ndarray,
               labels: jnp.ndarray, chunk: int = 1024) -> jnp.ndarray:
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    w = (embed_params["unembed"] if not cfg.tie_embeddings
         else embed_params["tok"].T)
    hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size

    @jax.checkpoint
    def body(carry, xs):
        h, lab = xs
        lg = jnp.einsum("bcd,dv->bcv", h, w.astype(h.dtype),
                        preferred_element_type=jnp.float32)
        lg = soft_cap(lg, cfg.final_softcap)
        lg = jnp.where(vocab_ok[None, None], lg, _NEG)
        lg = constrain(lg, ("batch", None, "vocab_act"))
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    from repro.models.flags import unroll_scans
    if unroll_scans():
        total = jnp.zeros((), jnp.float32)
        for j in range(nc):
            total, _ = body(total, (hs[j], ls[j]))
    else:
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (B * S)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray], *,
            remat: str = "none") -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if cfg.is_encdec:
        hidden, aux = encdec_forward(cfg, params, batch["tokens"],
                                     batch["enc_embeds"], mode="train",
                                     remat=remat)
    else:
        hidden, aux = lm_forward(cfg, params, batch["tokens"],
                                 positions=batch.get("positions"),
                                 mode="train", remat=remat)
    ce = chunked_ce(cfg, params["embed"], hidden, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------

def final_logits(cfg: ModelConfig, params, hidden: jnp.ndarray) -> jnp.ndarray:
    w = (params["embed"]["unembed"] if not cfg.tie_embeddings
         else params["embed"]["tok"].T)
    lg = jnp.einsum("bsd,dv->bsv", hidden, w.astype(hidden.dtype),
                    preferred_element_type=jnp.float32)
    return soft_cap(lg, cfg.final_softcap)


def prefill(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    """-> (last-token logits, cache)."""
    if cfg.is_encdec:
        hidden, _, cache = encdec_forward(cfg, params, batch["tokens"],
                                          batch["enc_embeds"], mode="prefill")
    else:
        hidden, _, cache = lm_forward(cfg, params, batch["tokens"],
                                      positions=batch.get("positions"),
                                      mode="prefill")
    lg = final_logits(cfg, params, hidden[:, -1:])
    return lg, cache


def decode_step(cfg: ModelConfig, params, cache, tokens: jnp.ndarray,
                cur_len: jnp.ndarray):
    """tokens: (B,1). -> (logits (B,1,V), new_cache)."""
    if cfg.is_encdec:
        hidden, _, new_cache = encdec_forward(cfg, params, tokens,
                                              mode="decode", cache=cache,
                                              cur_len=cur_len)
    else:
        hidden, _, new_cache = lm_forward(cfg, params, tokens, mode="decode",
                                          cache=cache, cur_len=cur_len)
    lg = final_logits(cfg, params, hidden)
    return lg, new_cache


def paged_decode_step(cfg: ModelConfig, params, cache, tokens: jnp.ndarray,
                      seq_lens: jnp.ndarray, block_table: jnp.ndarray,
                      shard=None):
    """Paged-KV decode step for the continuous-batching scheduler.

    tokens: (B,1); seq_lens: (B,) per-sequence live lengths; block_table:
    (B, n_pg) page ids into the pools in ``cache`` (see
    ``repro.serving.paged_cache``). -> (logits (B,1,V), new_cache).

    ``shard`` (a ``repro.parallel.context.ShardGroup``, tp > 1) runs the
    tensor-parallel path: head-sharded attention over per-shard page pools
    and expert-sharded MoE, with the logits computed from the gathered
    hidden state exactly as at tp=1 — the byte-identity contract
    serve_bench's ``--tp`` gate enforces.
    """
    hidden, _, new_cache = lm_forward(cfg, params, tokens,
                                      mode="paged_decode", cache=cache,
                                      cur_len=seq_lens,
                                      block_table=block_table, shard=shard)
    lg = final_logits(cfg, params, hidden)
    return lg, new_cache


def paged_verify_step(cfg: ModelConfig, params, cache, tokens: jnp.ndarray,
                      seq_lens: jnp.ndarray, live: jnp.ndarray,
                      block_table: jnp.ndarray, shard=None):
    """Multi-token speculative verify through the fused paged-prefill path
    (attention-only archs; SSM/hybrid verify is the scheduler's sequential
    scan, exactly like chunked prefill's split).

    tokens: (B, n) — per stream, row 0 its last real token, rows
    ``1..live-1`` its draft tokens, each landing at absolute position
    ``seq_lens[b] + t``; ``live``: (B,) live rows (0 for a non-decoding
    slot — its rows are routed to the sink page). -> (logits (B, n, V),
    new_cache).

    A draft batch *is* a prompt chunk whose token ids happen to be
    speculative: the chunk's K/V lands directly in the stream's pages, the
    per-row causal mask makes row ``t`` attend prefix + rows ``<= t``, and
    the pages are gathered once per stream instead of once per row (the
    old batched-rows decode trick) — so verify also inherits the Pallas
    write+attend kernels under ``flags.prefill_kernel``. Unlike prefill,
    *every* row's logits are returned: per-row argmax gives the target
    tokens greedy acceptance compares drafts against, byte-identical to
    spec-off decoding. Rejected rows' K/V stay masked by ``seq_lens``
    (which only advances past accepted tokens) and are overwritten in
    place by later real tokens.
    """
    hidden, _, new_cache = lm_forward(cfg, params, tokens,
                                      mode="paged_prefill", cache=cache,
                                      cur_len=seq_lens, chunk_len=live,
                                      block_table=block_table, shard=shard)
    lg = final_logits(cfg, params, hidden)
    return lg, new_cache


def paged_prefill_step(cfg: ModelConfig, params, cache, tokens: jnp.ndarray,
                       start: jnp.ndarray, chunk_len: jnp.ndarray,
                       block_table: jnp.ndarray, shard=None):
    """Fused chunked-prefill step: land one prompt chunk per sequence
    directly in its pages and attend prefix+chunk in the same program.

    tokens: (B,S) chunk token ids (rows past ``chunk_len[b]`` are padding);
    start: (B,) tokens already in the pages; block_table: (B, n_pg).
    -> (hidden (B,S,D), new_cache). Returns hidden states, not logits —
    callers slice the last live row first (``final_logits`` over a full
    chunk of rows would be wasted vocab-width work; only the final chunk's
    last row seeds decoding).
    """
    hidden, _, new_cache = lm_forward(cfg, params, tokens,
                                      mode="paged_prefill", cache=cache,
                                      cur_len=start, chunk_len=chunk_len,
                                      block_table=block_table, shard=shard)
    return hidden, new_cache


# ---------------------------------------------------------------------------
# cache schema (ParamSpec tree -> reuse init/abstract machinery)
# ---------------------------------------------------------------------------

def _to_spec(entry) -> ParamSpec:
    shape, axes, dtype = entry
    return ParamSpec(tuple(shape), tuple(axes), init="zeros", dtype=str(dtype))


def _layer_cache_schema(cfg: ModelConfig, idx: int, batch: int,
                        capacity: int) -> Dict[str, ParamSpec]:
    kind = cfg.block_kind(idx)
    if kind == "ssm":
        raw = ssm_mod.ssm_cache_spec(cfg, batch)
    else:
        raw = attn_mod.kv_cache_spec(cfg, batch, capacity,
                                     local=(kind == "attn_local"))
    return {k: _to_spec(v) for k, v in raw.items()}


def cache_schema(cfg: ModelConfig, batch: int, capacity: int) -> Dict[str, Any]:
    if cfg.is_encdec:
        hd = cfg.resolved_head_dim
        self_c = {str(i): _layer_cache_schema(cfg, i, batch, capacity)
                  for i in range(cfg.n_layers)}
        cross = {str(i): {
            "k": ParamSpec((batch, cfg.enc_positions, cfg.n_heads, hd),
                           ("batch", None, "heads_act", None),
                           init="zeros", dtype=cfg.dtype),
            "v": ParamSpec((batch, cfg.enc_positions, cfg.n_heads, hd),
                           ("batch", None, "heads_act", None),
                           init="zeros", dtype=cfg.dtype),
        } for i in range(cfg.n_layers)}
        return {"self": self_c, "cross": cross}
    from repro.models.transformer import stack_schema
    prefix, period, n_periods = depth_plan(cfg)
    out: Dict[str, Any] = {}
    if prefix:
        out["prefix"] = {str(i): _layer_cache_schema(cfg, i, batch, capacity)
                         for i in range(prefix)}
    out["stack"] = {
        str(p): stack_schema(_layer_cache_schema(cfg, prefix + p, batch,
                                                 capacity), n_periods)
        for p in range(period)}
    return out
