"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE every other
layer (16 experts, top-2).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536 [arXiv:2403.19887; hf]

The SSM layers use the Mamba-2 SSD formulation (TPU adaptation; see
DESIGN.md) with Jamba's published state size (d_state=16, d_conv=4,
expand=2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    # attention at index 4 of each 8-layer period (1:7 attn:mamba)
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    n_routed_experts=16,
    moe_top_k=2,
    expert_d_ff=14336,
    moe_period=2,
    ssm_state=16,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-reduced",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    n_routed_experts=4,
    moe_top_k=2,
    expert_d_ff=128,
    moe_period=2,
    ssm_state=8,
    ssm_headdim=16,
    ssm_expand=2,
    tie_embeddings=False,
)
