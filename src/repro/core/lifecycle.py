"""Cluster lifecycle: stop / start / extend / shrink / replace (use cases 2-4).

Paper semantics preserved:
  * stop halts billing (use case 2);
  * start brings *slaves up first, then the master* (use case 3) and triggers
    master re-discovery because private IPs changed;
  * extend adds instances which the master enumerates with fresh ranks
    (use case 4);
plus the pieces a 1000-node fleet needs: a warm-spare pool and single-node
replacement that keeps logical ranks stable (checkpoints stay valid).
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.discovery import Node
from repro.core.provisioner import Cluster, ClusterProvisioner, IMAGE_ID
from repro.core.simcloud import Instance, InstanceState, SimCloud


class LifecycleError(RuntimeError):
    pass


class ClusterLifecycle:
    def __init__(self, cloud: SimCloud, provisioner: ClusterProvisioner):
        self.cloud = cloud
        self.prov = provisioner
        self.spares: List[Instance] = []

    # ------------------------------------------------------------ stopping --
    def stop(self, cluster: Cluster) -> None:
        """Use case 2: stop every instance to halt billing."""
        self.cloud.stop_instances(cluster.instance_ids,
                                  cluster.access_key_id)
        cluster.log.emit(self.cloud.clock, "user", "stop_cluster",
                         count=len(cluster.instance_ids))

    # ------------------------------------------------------------ starting --
    def start(self, cluster: Cluster) -> List[str]:
        """Use case 3: slaves first, then master; master re-discovers IPs."""
        slave_ids = [s.instance_id for s in cluster.slaves]
        self.cloud.start_instances(slave_ids, cluster.access_key_id)
        cluster.log.emit(self.cloud.clock, "user", "start_slaves",
                         count=len(slave_ids))
        self.cloud.start_instances([cluster.master.instance_id],
                                   cluster.access_key_id)
        cluster.log.emit(self.cloud.clock, "user", "start_master")
        return self.prov.rediscover(cluster)

    # ----------------------------------------------------------- extension --
    def extend(self, cluster: Cluster, n_new: int,
               instance_type: Optional[str] = None) -> List[Node]:
        """Use case 4: add instances; the master assigns the next ranks."""
        itype = instance_type or (cluster.slaves[0].instance_type
                                  if cluster.slaves else "tpu-host-v5e-8")
        new = self.cloud.run_instances(
            count=n_new, instance_type=itype, region=cluster.region,
            image_id=IMAGE_ID, access_key_id=cluster.access_key_id,
            user_data={"role": "slave",
                       "access_key_id": cluster.access_key_id},
            spot=cluster.spot)
        cluster.slaves.extend(new)
        nodes = cluster.directory.add_slaves(new)
        for n in nodes:
            self.cloud.create_tags([n.instance_id],
                                   {"instacluster:role": n.hostname},
                                   cluster.access_key_id)
            cluster.security.temp_user_active[n.instance_id] = False
        cluster.log.emit(self.cloud.clock, "master", "extend_cluster",
                         added=[n.hostname for n in nodes])
        self.prov.rediscover(cluster)
        return nodes

    def shrink(self, cluster: Cluster, hostnames: List[str]) -> None:
        ids = []
        for hn in hostnames:
            node = cluster.directory.remove(hn)
            ids.append(node.instance_id)
        cluster.slaves = [s for s in cluster.slaves
                          if s.instance_id not in ids]
        self.cloud.terminate_instances(ids, cluster.access_key_id)
        cluster.log.emit(self.cloud.clock, "master", "shrink_cluster",
                         removed=hostnames)

    # -------------------------------------------------------------- spares --
    def provision_spares(self, cluster: Cluster, n: int) -> None:
        itype = (cluster.slaves[0].instance_type if cluster.slaves
                 else "tpu-host-v5e-8")
        self.spares.extend(self.cloud.run_instances(
            count=n, instance_type=itype, region=cluster.region,
            image_id=IMAGE_ID, access_key_id=cluster.access_key_id,
            user_data={"role": "spare",
                       "access_key_id": cluster.access_key_id}))
        cluster.log.emit(self.cloud.clock, "master", "provision_spares", n=n)

    def replace_failed(self, cluster: Cluster, hostname: str) -> Node:
        """Swap a dead host for a warm spare; the logical rank (and thus the
        sharding layout and checkpoint addressing) is unchanged."""
        node = cluster.directory.nodes.get(hostname)
        if node is None:
            raise LifecycleError(f"unknown host {hostname}")
        if not self.spares:
            raise LifecycleError("no warm spares available")
        spare = self.spares.pop(0)
        old_id = node.instance_id
        cluster.directory.replace_instance(hostname, spare)
        cluster.slaves = [s for s in cluster.slaves
                          if s.instance_id != old_id] + [spare]
        self.cloud.create_tags([spare.instance_id],
                               {"instacluster:role": hostname},
                               cluster.access_key_id)
        cluster.log.emit(self.cloud.clock, "master", "replace_host",
                         hostname=hostname, old=old_id,
                         new=spare.instance_id)
        self.prov.rediscover(cluster)
        return cluster.directory.nodes[hostname]
