"""Sharded checkpointing: atomic commits, async writes, resharding restore.

Layout:  <dir>/step_00000042/  manifest.json + one .npy per tree leaf.
Commits are atomic (write to ``.tmp`` dir, fsync, rename), so a crash
mid-save never corrupts the latest checkpoint — the restore path simply
picks the newest *committed* step (the paper's stop/restart story, hardened
for preemption). Restore reshards onto whatever mesh the cluster has *now*
(elastic resize), because leaves are stored unsharded and re-placed with
``jax.device_put`` against the caller's target shardings.
"""
from __future__ import annotations

import concurrent.futures as cf
import hashlib
import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, path: str = "") -> Dict[str, Any]:
    if isinstance(tree, dict):
        out: Dict[str, Any] = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{path}/{k}" if path else str(k)))
        return out
    return {path: tree}


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_writes: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = (cf.ThreadPoolExecutor(max_workers=2)
                      if async_writes else None)
        self._pending: List[cf.Future] = []

    # ---------------------------------------------------------------- save --
    def save(self, state: Any, step: int, *, blocking: bool = False):
        flat = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(state).items()}
        if self._pool is None or blocking:
            self._write(flat, step)
            return None
        fut = self._pool.submit(self._write, flat, step)
        self._pending.append(fut)
        return fut

    def wait(self) -> None:
        for f in self._pending:
            f.result()
        self._pending.clear()

    def _write(self, flat: Dict[str, np.ndarray], step: int) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for i, (path, arr) in enumerate(flat.items()):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256_16": hashlib.sha256(
                    arr.tobytes()[:1 << 20]).hexdigest()[:16],
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)           # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------- restore --
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *,
                target: Optional[Any] = None, verify: bool = False) -> Any:
        """Load a checkpoint; if ``target`` (a tree of ShapeDtypeStruct with
        shardings, or concrete arrays) is given, re-place each leaf with its
        target sharding — this is the elastic-resize path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        tgt_flat = _flatten(target) if target is not None else None
        flat: Dict[str, Any] = {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if verify:
                h = hashlib.sha256(arr.tobytes()[:1 << 20]).hexdigest()[:16]
                if h != meta["sha256_16"]:
                    raise IOError(f"checksum mismatch for {path} @ step {step}")
            if tgt_flat is not None and path in tgt_flat:
                tgt = tgt_flat[path]
                sharding = getattr(tgt, "sharding", None)
                arr = (jax.device_put(arr, sharding) if sharding is not None
                       else jnp.asarray(arr))
            else:
                arr = jnp.asarray(arr)
            flat[path] = arr
        return _unflatten(flat)
