"""Serving demo: batched prefill + greedy decode with the cache engine.

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch gemma2-2b]
(uses the arch's REDUCED config so it runs on CPU; the full configs are
exercised by the dry-run).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.serving import engine as E


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(REDUCED))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = REDUCED[args.arch]
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.rope_variant == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_positions, cfg.d_model), jnp.float32)

    t0 = time.time()
    lg, cache, cur = E.prefill(cfg, params, batch,
                               capacity=S + args.gen + 8)
    lg.block_until_ready()
    t_prefill = time.time() - t0
    print(f"{args.arch}: prefill {B}x{S} in {t_prefill*1e3:.0f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(
        jnp.int32)[:, None]
    t0 = time.time()
    toks, cache, cur = E.greedy_decode(cfg, params, cache, first, cur,
                                       args.gen)
    toks.block_until_ready()
    t_dec = time.time() - t0
    print(f"decode {args.gen} steps x {B} streams in {t_dec*1e3:.0f} ms "
          f"({B*args.gen/t_dec:.1f} tok/s)")
    print("sampled token ids (stream 0):", list(map(int, toks[0][:16])))


if __name__ == "__main__":
    main()
