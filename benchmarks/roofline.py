"""Roofline report: aggregates the dry-run JSONs into the §Roofline table.

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
emits a markdown table + CSV rows. No jax import — safe to run anywhere.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

DEFAULT_DIR = pathlib.Path(__file__).parent / "results" / "dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(directory=DEFAULT_DIR) -> List[Dict]:
    recs = []
    for p in sorted(pathlib.Path(directory).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def _key(r):
    return (r["arch"], SHAPE_ORDER.index(r["shape"])
            if r["shape"] in SHAPE_ORDER else 9, r["mesh"])


def markdown_table(recs: List[Dict], mesh: str = "pod16x16") -> str:
    rows = ["| arch | shape | comp s | mem s | coll s | dominant | "
            "useful FLOP ratio | roofline frac | peak GiB | lever |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=_key):
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped | — | — | — | {r['reason'][:40]}… |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR "
                        f"| — | — | — | {r.get('error','')[:40]} |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{r['dominant'][:-2]} | {r['useful_flop_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | {lever(r)} |")
    return "\n".join(rows)


def lever(r: Dict) -> str:
    """One-sentence 'what would move the dominant term down'."""
    dom = r["dominant"]
    per_op = r["collectives"]["per_op"]
    biggest_coll = max(per_op, key=lambda k: per_op[k]["operand_bytes"]) \
        if per_op else "none"
    if dom == "collective_s":
        return (f"cut {biggest_coll} bytes (SP-shard residuals / "
                f"reduce-scatter instead of all-reduce)")
    if dom == "memory_s":
        return ("reduce materialised intermediates (fuse masks/softmax, "
                "fewer fp32 upcasts, larger fusion regions)")
    return "increase arithmetic intensity (bigger blocks, fewer recomputes)"


def csv_rows(recs: List[Dict]) -> List[str]:
    out = []
    for r in sorted(recs, key=_key):
        tag = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("status") != "ok":
            out.append(f"{tag},,{r.get('status')}")
            continue
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        out.append(f"{tag},{bound*1e6:.0f},"
                   f"dom={r['dominant'][:-2]};frac={r['roofline_fraction']:.3f};"
                   f"useful={r['useful_flop_ratio']:.3f}")
    return out


def summary(recs: List[Dict]) -> Dict[str, float]:
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") not in ("ok", "skipped")]
    return {"ok": len(ok), "skipped": len(skipped), "error": len(err)}
