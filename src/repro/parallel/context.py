"""Parallel context: activation-sharding constraints usable from model code.

Model code names *logical* activation axes; the active ``ParallelCtx`` (set
by the train/serve step builders) maps them to mesh axes. With no context
(single-device smoke tests) constraints are no-ops, so model code never
needs to know whether it is distributed.

``ShardGroup`` is the serving fabric's unit of tensor parallelism: one
logical replica spanning ``tp`` devices on a model-parallel mesh axis.
Everything shard-aware — the head-sharded paged-decode path in
``repro.models.attention``, the per-shard page pools in
``repro.serving.paged_cache``, the per-shard budgets in
``repro.core.blueprint.serving_page_plan``, and the shard-group node
placement in ``repro.core.services`` / ``repro.autoscale.fleet`` — is
parameterised by one of these.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models.schema import resolve_pspec

# default logical activation-axis rules (planner may override per blueprint)
ACT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "act_seq": ("data",),        # sequence sharding (long-context decode)
    "heads_act": ("model",),
    "ff_act": ("model",),
    "experts_act": ("model",),
    "vocab_act": ("model",),
    "cache_seq": ("model",),     # decode-cache sequence dim
    "kv_heads": ("model",),
}

_STATE = threading.local()


@dataclass
class ParallelCtx:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]] = field(default_factory=lambda: dict(ACT_RULES))


def current() -> Optional[ParallelCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_parallel(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev = current()
    _STATE.ctx = ParallelCtx(mesh, {**ACT_RULES, **(rules or {})})
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


@dataclass(frozen=True)
class ShardGroup:
    """``tp`` devices on one model-parallel mesh axis acting as one logical
    serving replica.

    The group is the fabric's scale-*up* unit: a replica's page pools,
    attention heads, and MoE experts split ``tp`` ways across the group's
    members while the block table, allocator refcounts, and prefix index
    stay a single (logical) control plane — see docs/sharding.md.

    ``mesh`` is optional. With a mesh whose ``axis`` has size ``tp``, the
    sharded decode step runs under ``shard_map_compat`` (one program per
    device, the head-axis ``all_gather`` on the wire). Without one, the
    same per-shard body runs as an unrolled loop inside a single program —
    semantically identical, which is what makes the tp>1 vs tp=1
    byte-identity gate testable on any host.
    """
    tp: int = 1
    axis: str = "model"
    mesh: Optional[Mesh] = None

    def __post_init__(self):
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.mesh is not None:
            sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            if self.axis not in sizes:
                raise ValueError(
                    f"mesh has no {self.axis!r} axis (axes: "
                    f"{tuple(self.mesh.axis_names)})")
            if sizes[self.axis] != self.tp:
                raise ValueError(
                    f"mesh {self.axis!r} axis has size {sizes[self.axis]}, "
                    f"shard group needs {self.tp}")

    @property
    def is_sharded(self) -> bool:
        return self.tp > 1

    @property
    def use_shard_map(self) -> bool:
        """True when the group should run one program per device."""
        return self.mesh is not None and self.tp > 1

    def validate_model(self, cfg) -> None:
        """Raise if ``cfg`` cannot split ``tp`` ways (head/expert counts)."""
        if self.tp == 1:
            return
        if cfg.attn_impl == "mla":
            raise ValueError(
                f"{cfg.name}: MLA decode keeps the dense absorbed path; "
                "shard groups cover GQA/SSM/MoE paged serving")
        problems = []
        if cfg.n_heads % self.tp:
            problems.append(f"n_heads {cfg.n_heads}")
        if cfg.n_kv_heads % self.tp:
            problems.append(f"n_kv_heads {cfg.n_kv_heads}")
        if cfg.n_routed_experts and cfg.n_routed_experts % self.tp:
            problems.append(f"n_routed_experts {cfg.n_routed_experts}")
        if problems:
            raise ValueError(
                f"{cfg.name}: tp={self.tp} must divide "
                + ", ".join(problems))

    def shard_heads(self, n: int) -> int:
        """Heads (query, kv, or expert count) one shard owns."""
        assert n % self.tp == 0, (n, self.tp)
        return n // self.tp


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply with_sharding_constraint mapping logical axes via the context."""
    ctx = current()
    if ctx is None:
        return x
    pspec = resolve_pspec(tuple(axes), tuple(x.shape), ctx.rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, pspec))
