"""Fabric router: one front-end over k scheduler replicas on cluster nodes.

PR 1–2 built a continuous-batching scheduler and taught it to resize, but
one scheduler is one implicit node — the cluster the provisioning layer
builds never shows up in serving throughput. The router makes "serve" a
fleet service:

* **arrival queue** — ``submit`` lands requests in one fleet-wide queue
  gated on the fleet clock; each tick the router routes everything due.
* **routing** — least-outstanding-reserved-pages: candidates are live
  replicas ordered by ``(outstanding_pages, replica_id)`` (the id is the
  deterministic tie-break, so a fleet run is replayable); the first
  candidate whose pool could ever hold the request wins — a request too
  big for the least-loaded replica's pool *spills over* to the next.
  ``route_policy="prefix-affinity"`` instead orders by longest cached
  prompt prefix first (then the least-pages key): the replica already
  holding a request's persona pages admits it with a prefix-cache hit —
  skipping the shared prefill and sharing the pages — where any other
  replica would duplicate both. All-miss requests degrade to least-pages,
  so affinity also spreads *new* prefixes across the fleet.
* **drain / fail** — ``drain_replica`` stops new routing while the
  replica's streams finish (graceful scale-in: the fleet autoscaler's
  scale-in path); ``fail_replica`` (heartbeat DEAD, spot preemption)
  surrenders unfinished streams, and the router re-prefills each one's
  ``prompt + emitted tokens`` on a surviving replica. Greedy decoding
  depends only on the prefix, so the re-routed continuation is
  token-identical for dense/SSM archs (MoE shares the scheduler's
  capacity-coupling caveat).
* **clocks** — replicas keep private scheduler clocks (a replica added at
  fleet tick 40 starts at 0); the router stamps ``finish_step`` and
  restores ``arrival_step`` on the fleet clock when it collects a finished
  request, so latency percentiles are comparable fleet-wide.

Placement is by hostname: ``AmbariServer.provision_serving(replicas=k)``
picks k nodes from the ``NodeDirectory`` and the fleet autoscaler
(``repro.autoscale.fleet``) acquires/releases nodes through
``ClusterLifecycle`` as it adds/removes replicas. ``fail_host`` is the
heartbeat hook: wire ``monitor.on_dead(router.fail_host)``.

**Disaggregation** (``disagg=k``): the first ``k`` replicas become
*prefill* specialists and the rest *decode* specialists. Every prompt
routes to a prefill replica (prefix-affinity and spillover unchanged);
when its prefill completes the stream parks and the router's migration
pass hands its KV pages verbatim to the least-loaded decode replica that
can adopt it (worst-case reservation on the decode side, so an adopted
stream can never OOM). No decode-capable target with room means the
stream stays parked — natural backpressure on the prefill side. Migration
keeps the same ``Request`` object, so fleet-clock latency accounting and
the re-route machinery are untouched; a prefill replica dying mid-prompt
falls back to the existing re-prefill path.

With ``tp > 1`` every fabric member is a *shard group*: one logical
scheduler spanning tp nodes (``provision_serving(tp=k)`` hands out
contiguous node sets, the fleet autoscaler acquires/releases tp nodes per
scaling decision), and ``fail_host`` fails the whole group when any
member dies — unless the fleet controller replaces the member from a warm
spare first, in which case the group's streams never notice. Routing is
tp-agnostic: pages are logical, so ``outstanding_pages`` and the prefix
index compare across members of different tp.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Deque, Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.obs.metrics import (Histogram, MetricsRegistry, StatsView,
                               SECONDS_BUCKETS, TICK_BUCKETS)
from repro.serving import paged_cache as PC
from repro.serving.replica import ServingReplica
from repro.serving.request import Request, make_request, worst_case_pages
from repro.serving.scheduler import supports_paged

ROUTE_POLICIES = ("least-pages", "round-robin", "prefix-affinity")


class ServingRouter:
    """Front-end owning the fleet arrival queue and k scheduler replicas.

    Constructor knobs mirror one replica's scheduler (``max_slots``,
    ``page_size``, ``num_pages``, ``max_seq_len`` are *per replica* — use
    ``serving_page_plan(..., replicas=k)`` for a coherent split) plus the
    fleet ones: ``replicas`` initial fleet size, ``placement`` hostnames,
    ``route_policy`` in ``ROUTE_POLICIES``.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, replicas: int = 1,
                 max_slots: int = 4, page_size: int = 16,
                 num_pages: Optional[int] = None, max_seq_len: int = 512,
                 placement: Optional[Sequence[Any]] = None,
                 route_policy: str = "least-pages",
                 prefix_cache: Optional[bool] = None, tp: int = 1,
                 prefill_budget: Optional[int] = None, disagg: int = 0,
                 spec_k: Optional[int] = None, spec_draft=None,
                 host_pages: Optional[int] = None, tenant_quotas=None,
                 swap_crossover: Optional[int] = None):
        if not supports_paged(cfg):
            raise NotImplementedError(
                f"{cfg.name}: the fabric routes over paged schedulers; "
                "MLA/enc-dec archs stay on repro.serving.engine")
        if replicas < 1:
            raise ValueError("need at least one replica")
        if route_policy not in ROUTE_POLICIES:
            raise ValueError(f"route_policy must be one of {ROUTE_POLICIES}")
        if disagg and not 1 <= disagg < replicas:
            raise ValueError(
                f"disagg={disagg} needs 1 <= prefill replicas < "
                f"replicas ({replicas}) so both roles exist")
        self.cfg = cfg
        self.params = params
        # tp > 1: every fabric member is a shard group — tp nodes, one
        # logical scheduler (placement entries become hostname *lists*)
        self.replica_kw = dict(max_slots=max_slots, page_size=page_size,
                               num_pages=num_pages, max_seq_len=max_seq_len,
                               prefix_cache=prefix_cache, tp=tp,
                               prefill_budget=prefill_budget,
                               spec_k=spec_k, spec_draft=spec_draft,
                               host_pages=host_pages,
                               tenant_quotas=tenant_quotas,
                               swap_crossover=swap_crossover)
        # prefill/decode disaggregation: True once the fleet splits roles
        self.disagg = disagg > 0
        self.route_policy = route_policy
        self.replicas: Dict[int, ServingReplica] = {}
        self.waiting: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self.step_idx = 0
        self._rid = 0
        self._next_replica = 0
        self._rr_cursor = 0                  # round-robin route state
        self._arrival: Dict[int, int] = {}   # rid -> fleet arrival tick
        # continuation -> original request (re-routes after a replica loss)
        self._parents: Dict[int, Request] = {}
        # fleet-level observability plane (repro.obs): the router's own
        # counters live on a fleet registry (StatsView keeps the dict
        # idioms), plus fleet-clock latency and per-replica step-wall
        # histograms. ``set_tracer`` threads one lifecycle tracer through
        # every replica on the *fleet* clock; ``enable_profiling`` shares
        # one kernel profiler fleet-wide. All of it is read-only.
        self.registry = MetricsRegistry(labels={"plane": "fleet"})
        self.stats = StatsView({
            k: self.registry.counter(f"fleet_{k}")
            for k in ("routed", "spillovers", "reroutes", "replicas_added",
                      "replicas_removed", "migrations")})
        self.h_latency = self.registry.histogram(
            "fleet_latency_ticks", TICK_BUCKETS, unit="ticks",
            help="fleet-clock ticks from arrival to finish")
        self.h_tick_wall = self.registry.histogram(
            "fleet_tick_wall_seconds", SECONDS_BUCKETS, unit="seconds",
            help="wall seconds of one replica step within a fleet tick")
        self.tracer = None
        self._profiler = None
        # per-tick per-replica step wall times (seconds), recorded only when
        # a bench turns it on: [{replica_id: (role, dt)}, ...]
        self.record_timing = False
        self.tick_timings: List[Dict[int, tuple]] = []
        # counters of replicas that already left the fleet, so fleet totals
        # survive drain-remove and failure
        self._retired_stats: Dict[str, int] = {}
        # same, for departed replicas' latency histograms (bucket counts
        # merge exactly, so fleet quantiles survive churn too)
        self._retired_hists: Dict[str, Histogram] = {}
        # (tick, [reserved_pages per live replica]) when >= 2 are live and
        # every one has work — the steady-state balance samples
        self.balance_log: List[tuple] = []
        placement = list(placement or [])
        for i in range(replicas):
            spot = placement[i] if i < len(placement) else None
            kw = {}
            if disagg:
                kw["role"] = "prefill" if i < disagg else "decode"
            if spot is None or isinstance(spot, str):
                self.add_replica(hostname=spot, **kw)
            else:
                self.add_replica(hostnames=spot, **kw)

    # ----------------------------------------------------------- topology --
    def add_replica(self, *, hostname: Optional[str] = None,
                    hostnames: Optional[Sequence[str]] = None,
                    **overrides: Any) -> ServingReplica:
        """Add a fabric member (``overrides`` patch the default replica
        sizing — fleet members become heterogeneous the moment per-replica
        autoscalers resize them, so routing never assumes symmetry). A
        shard-group member (tp > 1) takes ``hostnames`` — its ``tp`` node
        placement — instead of a single ``hostname``."""
        rep = ServingReplica.build(
            self.cfg, self.params, self._next_replica, hostname=hostname,
            hostnames=hostnames, **{**self.replica_kw, **overrides})
        self.replicas[rep.replica_id] = rep
        self._next_replica += 1
        self.stats["replicas_added"] += 1
        self._wire_obs(rep)
        return rep

    # ------------------------------------------------------- observability --
    def _wire_obs(self, rep: ServingReplica) -> None:
        """Thread the fleet tracer/profiler into a (new) replica."""
        if self.tracer is not None:
            rep.sched.set_tracer(self.tracer, own_clock=False)
            self.tracer.set_process_name(
                rep.replica_id, f"replica-{rep.replica_id} ({rep.role})")
        if self._profiler is not None:
            rep.sched.profiler = self._profiler

    def set_tracer(self, tracer) -> None:
        """Attach one lifecycle tracer fleet-wide. Every replica's hooks
        stamp the *fleet* clock (replica clocks drift through idle-gap
        skipping), so all spans share a single timeline."""
        self.tracer = tracer
        for rep in self.replicas.values():
            self._wire_obs(rep)

    def enable_profiling(self, profiler=None):
        """One shared kernel profiler across the fleet (fleet-total
        dispatch timings; see ``repro.obs.profile``)."""
        if profiler is None:
            from repro.obs.profile import KernelProfiler
            profiler = KernelProfiler(self.cfg, tp=self.replica_kw["tp"])
        self._profiler = profiler
        for rep in self.replicas.values():
            rep.sched.profiler = profiler
        return profiler

    def expose(self) -> str:
        """Prometheus text exposition: the fleet registry plus every live
        replica's registry (labeled per replica by ``ServingReplica``)."""
        parts = [self.registry.expose()]
        for rep in sorted(self.replicas.values(),
                          key=lambda r: r.replica_id):
            parts.append(rep.sched.registry.expose())
        return "".join(parts)

    def fleet_histogram(self, name: str) -> Optional[Histogram]:
        """Fleet-wide merge of a per-replica histogram (live replicas plus
        retired ones); None if no replica ever registered it."""
        agg: Optional[Histogram] = None
        sources = [rep.sched.registry.get(name)
                   for rep in sorted(self.replicas.values(),
                                     key=lambda r: r.replica_id)]
        sources.append(self._retired_hists.get(name))
        for m in sources:
            if not isinstance(m, Histogram):
                continue
            if agg is None:
                agg = Histogram(name, m.bounds, help=m.help, unit=m.unit)
            agg.merge(m)
        return agg

    def drain_replica(self, replica_id: int) -> ServingReplica:
        rep = self.replicas[replica_id]
        rep.drain()
        return rep

    def undrain_replica(self, replica_id: int) -> ServingReplica:
        rep = self.replicas[replica_id]
        rep.undrain()
        return rep

    def remove_replica(self, replica_id: int) -> Optional[str]:
        """Remove a drained-and-empty (or failed) replica; returns its
        hostname so the caller can release the node."""
        rep = self.replicas[replica_id]
        if not rep.failed and not rep.idle:
            raise RuntimeError(
                f"replica {replica_id} still holds {rep.num_unfinished} "
                "unfinished requests; drain it first")
        self._retire_stats(rep)
        del self.replicas[replica_id]
        self.stats["replicas_removed"] += 1
        return rep.hostname

    def _retire_stats(self, rep: ServingReplica) -> None:
        for k, v in rep.stats().items():
            self._retired_stats[k] = self._retired_stats.get(k, 0) + v
        for m in rep.sched.registry.metrics():
            if isinstance(m, Histogram):
                agg = self._retired_hists.setdefault(
                    m.name, Histogram(m.name, m.bounds, help=m.help,
                                      unit=m.unit))
                agg.merge(m)

    def fail_replica(self, replica_id: int) -> List[Request]:
        """Replica death (heartbeat DEAD / spot preemption): surrender its
        unfinished streams and queue token-identical continuations. A
        replica already failed directly (member death observed ahead of
        the router) is simply retired from the fleet — its hostnames and
        streams were purged by ``ServingReplica.fail()``."""
        rep = self.replicas[replica_id]
        if rep.failed:
            self._retire_stats(rep)
            del self.replicas[replica_id]
            self.stats["replicas_removed"] += 1
            return []
        lost = rep.fail()
        if self.tracer is not None:
            self.tracer.instant("failover", t=self.step_idx,
                                replica=replica_id, lost=len(lost))
        rerouted = []
        for req in lost:
            rerouted.append(self._requeue(req))
        self.stats["reroutes"] += len(rerouted)
        self._retire_stats(rep)
        del self.replicas[replica_id]
        self.stats["replicas_removed"] += 1
        return rerouted

    def fail_host(self, hostname: str) -> List[Request]:
        """Heartbeat hook: fail every replica with a member on
        ``hostname`` — losing one shard of a tp-way group loses the whole
        group's device state (unless the fleet controller intercepts the
        death first and swaps the member from a warm spare)."""
        out = []
        for rid in [r.replica_id for r in self.replicas.values()
                    if hostname in r.hostnames]:
            out.extend(self.fail_replica(rid))
        return out

    def _requeue(self, req: Request) -> Request:
        """Queue the continuation of a lost stream at the *front* (it has
        already waited once; re-prefill as soon as capacity exists)."""
        tr = self.tracer
        if tr is not None:
            # the lost stream's open span (whichever state it died in)
            for name in ("decode", "parked", "queued"):
                tr.end(name, req.rid, t=self.step_idx, lost=True)
        orig = self._parents.pop(req.rid, req)   # chain continuations
        orig.replica = None
        orig.reroutes += 1
        if req is not orig:
            orig.out_tokens.extend(req.out_tokens)
            orig.cached_tokens += req.cached_tokens
        if orig.remaining_tokens == 0:
            # lost after its last token was emitted: it is simply finished
            self._collect(orig)
            return orig
        cont = make_request(self._rid, list(orig.prompt) + orig.out_tokens,
                            orig.remaining_tokens,
                            arrival_step=self.step_idx)
        self._rid += 1
        self._parents[cont.rid] = orig
        self.waiting.appendleft(cont)
        if tr is not None:
            tr.instant("reroute", rid=req.rid, t=self.step_idx,
                       cont=cont.rid,
                       emitted=len(orig.out_tokens))
            tr.begin("queued", cont.rid, t=self.step_idx)
        return cont

    # --------------------------------------------------------- submission --
    def submit(self, prompt, max_new_tokens: int,
               arrival_step: int = 0, priority: int = 1,
               tenant: str = "default") -> Request:
        req = make_request(self._rid, prompt, max_new_tokens, arrival_step,
                           priority=priority, tenant=tenant)
        self._rid += 1
        if not any(rep.fits(req) for rep in self.replicas.values()
                   if rep.role != "decode"):
            raise ValueError(
                f"request needs {req.plen + req.max_new_tokens} positions / "
                f"{worst_case_pages(req, self.replica_kw['page_size'])} "
                f"pages — no replica in the fleet could ever admit it")
        if self.disagg and not any(
                rep.fits(req) for rep in self.replicas.values()
                if rep.role != "prefill"):
            raise ValueError(
                f"request needs {req.plen + req.max_new_tokens} positions "
                "but no decode-role replica could ever adopt it after "
                "prefill")
        self._arrival[req.rid] = arrival_step
        self.waiting.append(req)
        if self.tracer is not None:
            self.tracer.begin("queued", req.rid, t=arrival_step)
        return req

    # ------------------------------------------------------------ routing --
    def _live(self) -> List[ServingReplica]:
        return sorted((r for r in self.replicas.values() if r.live),
                      key=lambda r: r.replica_id)

    def _routable(self) -> List[ServingReplica]:
        """Live replicas new prompts may route to — decode specialists only
        take work through the migration pass."""
        return [r for r in self._live() if r.role != "decode"]

    def _candidates(self, live: List[ServingReplica],
                    req: Request) -> List[ServingReplica]:
        if self.route_policy == "round-robin":
            k = len(live)
            order = [live[(self._rr_cursor + i) % k] for i in range(k)]
            self._rr_cursor = (self._rr_cursor + 1) % max(k, 1)
            return order
        if self.route_policy == "prefix-affinity":
            # longest cached prefix first — the replica already holding the
            # request's prefix pages skips that much prefill and shares the
            # pages instead of duplicating them. Least-outstanding-pages
            # breaks affinity ties (including the all-miss case, where this
            # degrades to the default policy), replica id breaks the rest,
            # so placement stays deterministic and replayable.
            return sorted(live, key=lambda r: (
                -r.prefix_match_len(req.prompt), r.outstanding_pages,
                r.replica_id))
        return sorted(live, key=lambda r: (r.outstanding_pages,
                                           r.replica_id))

    def route_due(self) -> int:
        """Assign every due waiting request to a replica; returns count."""
        routed = 0
        deferred: List[Request] = []
        while self.waiting:
            if self.waiting[0].arrival_step > self.step_idx:
                break
            req = self.waiting.popleft()
            live = self._routable()
            placed = False
            for i, rep in enumerate(self._candidates(live, req)):
                if rep.fits(req):
                    if i > 0:
                        self.stats["spillovers"] += 1
                    if self.tracer is not None:
                        self.tracer.instant("routed", rid=req.rid,
                                            t=self.step_idx,
                                            replica=rep.replica_id,
                                            spillover=i > 0)
                    rep.accept(req)
                    routed += 1
                    placed = True
                    break
            if not placed:
                # no live replica can ever hold it right now (e.g. every
                # fleet member is draining): hold at the front until the
                # fleet changes shape
                deferred.append(req)
        for req in reversed(deferred):
            self.waiting.appendleft(req)
        self.stats["routed"] += routed
        return routed

    # --------------------------------------------------------------- step --
    @property
    def num_unfinished(self) -> int:
        return (len(self.waiting)
                + sum(r.num_unfinished for r in self.replicas.values()))

    @property
    def pending_due(self) -> int:
        return sum(r.arrival_step <= self.step_idx for r in self.waiting)

    def _collect(self, req: Request) -> None:
        req.finish_step = self.step_idx
        req.arrival_step = self._arrival.pop(req.rid, req.arrival_step)
        self.finished.append(req)
        self.h_latency.observe(req.finish_step - req.arrival_step)

    def _migrate_ready(self) -> int:
        """Hand parked prefilled streams to decode-capable replicas.

        Donors drain oldest-parked-first; each stream goes to the live
        non-prefill replica with the fewest outstanding pages that can
        adopt it (free slot + full worst-case reservation). A stream with
        no adoptable target stays parked and retries next tick — the
        backpressure that keeps prefill replicas from outrunning decode
        capacity."""
        moved = 0
        for donor in sorted(self.replicas.values(),
                            key=lambda r: r.replica_id):
            if donor.failed or donor.role != "prefill":
                continue
            for slot in donor.handoff_ready():
                req = donor.sched.slot_req[slot]
                targets = sorted(
                    (r for r in self._live() if r.role != "prefill"),
                    key=lambda r: (r.outstanding_pages, r.replica_id))
                for t in targets:
                    if t.can_adopt(req):
                        n_pages = len(donor.sched.slot_pages[slot])
                        t.adopt(req, donor, slot)
                        moved += 1
                        if self.tracer is not None:
                            self.tracer.instant(
                                "page_migration", rid=req.rid,
                                t=self.step_idx, replica=t.replica_id,
                                src=donor.replica_id, dst=t.replica_id,
                                pages=n_pages,
                                bytes=PC.migration_bytes(
                                    self.cfg, n_pages,
                                    self.replica_kw["page_size"]))
                        break
        self.stats["migrations"] += moved
        return moved

    def step(self, max_fuse: int = 16) -> List[Request]:
        """One fleet tick: route due arrivals, step every replica once,
        migrate parked prefilled streams to decode replicas, collect
        finishes (joining re-routed continuations to their originals),
        advance the fleet clock."""
        if self.tracer is not None:
            self.tracer.set_tick(self.step_idx)
        self.route_due()
        done_now: List[Request] = []
        timing: Dict[int, tuple] = {}
        for rep in sorted(self.replicas.values(),
                          key=lambda r: r.replica_id):
            if rep.failed:
                continue
            t0 = time.perf_counter()
            stepped = rep.step(max_fuse=max_fuse)
            dt = time.perf_counter() - t0
            self.h_tick_wall.observe(dt)
            if self.record_timing:
                timing[rep.replica_id] = (rep.role, dt)
            for req in stepped:
                orig = self._parents.pop(req.rid, None)
                if orig is not None:
                    orig.out_tokens.extend(req.out_tokens)
                    orig.cached_tokens += req.cached_tokens
                    req = orig
                self._collect(req)
                done_now.append(req)
        if self.record_timing:
            self.tick_timings.append(timing)
        self._migrate_ready()
        if len(self.replicas) >= 2:
            live = self._live()
            if len(live) >= 2 and all(r.sched.num_active > 0 for r in live):
                self.balance_log.append(
                    (self.step_idx, [r.reserved_pages for r in live]))
        self.step_idx += 1
        return done_now

    def run(self, max_steps: int = 100_000,
            max_fuse: int = 16) -> List[Request]:
        while self.num_unfinished and max_steps:
            self.step(max_fuse=max_fuse)
            max_steps -= 1
        if self.num_unfinished:
            raise RuntimeError(
                f"router run() exhausted max_steps with "
                f"{self.num_unfinished} unfinished requests")
        return self.finished

    # ------------------------------------------------- role-split signals --
    def live_by_role(self, role: str) -> List[ServingReplica]:
        return [r for r in self._live() if r.role == role]

    def prefill_backlog(self) -> int:
        """Prompt tokens awaiting prefill fleet-wide: due queued prompts
        plus every prefill-capable replica's in-flight chunk remainders —
        the prefill-role autoscaling signal."""
        t = sum(r.plen for r in self.waiting
                if r.arrival_step <= self.step_idx)
        for rep in self.replicas.values():
            if not rep.failed and rep.role != "decode":
                t += rep.sched.prefill_backlog
        return t

    def decode_demand(self) -> int:
        """Streams that need (or are about to need) a decode slot: active
        and queued streams on decode-capable replicas plus prefilled
        streams parked for handoff — the decode-role autoscaling signal."""
        n = 0
        for rep in self.replicas.values():
            if rep.failed:
                continue
            if rep.role == "prefill":
                n += len(rep.handoff_ready())
            else:
                n += rep.num_unfinished
        return n

    # ------------------------------------------------------------ metrics --
    def prefix_hit_rate(self) -> float:
        """Fleet-wide prefix-cache hit rate over all prefills so far,
        retired replicas included — the single definition shared by
        ``fleet_stats`` and the fleet autoscaler's telemetry."""
        hits = self._retired_stats.get("prefix_hits", 0)
        prefills = self._retired_stats.get("prefills", 0)
        for r in self.replicas.values():
            hits += r.sched.stats["prefix_hits"]
            prefills += r.sched.stats["prefills"]
        return hits / prefills if prefills else 0.0

    def imbalance(self) -> Optional[float]:
        """Mean steady-state reserved-page imbalance (max-min over mean)
        across the balance samples; None when the fleet never had two busy
        replicas at once."""
        if not self.balance_log:
            return None
        vals = []
        for _, pages in self.balance_log:
            mean = sum(pages) / len(pages)
            if mean > 0:
                vals.append((max(pages) - min(pages)) / mean)
        return sum(vals) / len(vals) if vals else None

    def fleet_stats(self) -> Dict[str, Any]:
        per_replica = {rid: rep.stats() for rid, rep in
                       sorted(self.replicas.items())}
        out: Dict[str, Any] = dict(self.stats)
        out["fleet_ticks"] = self.step_idx
        out["live_replicas"] = len(self._live())
        for key in ("tokens_out", "decode_steps", "prefills",
                    "prefix_hits", "cached_tokens", "cow_forks",
                    "prefill_chunk_tokens", "migrations_in",
                    "migrations_out", "prefill_dispatches",
                    "prefill_compiles", "spec_ticks", "spec_drafted",
                    "spec_accepted", "swap_outs", "swap_out_pages",
                    "swap_ins", "swap_in_pages", "swap_reprefills",
                    "host_evictions", "quota_blocked", "index_evictions"):
            out[key] = (sum(s.get(key, 0) for s in per_replica.values())
                        + self._retired_stats.get(key, 0))
        # tier gauges: summed over *live* replicas only (retired replicas'
        # tiers died with them, so their last gauge values must not linger)
        for key in ("host_pages_used", "retained_pages"):
            out[key] = sum(s.get(key, 0) for rid, s in per_replica.items()
                           if self.replicas[rid].live)
        # derived, not summed: the fleet accept rate over all drafts so far
        out["spec_accept_rate"] = round(
            out["spec_accepted"] / max(out["spec_drafted"], 1), 4)
        out["prefix_hit_rate"] = round(self.prefix_hit_rate(), 3)
        imb = self.imbalance()
        if imb is not None:
            out["reserved_page_imbalance"] = round(imb, 3)
        out["per_replica"] = per_replica
        return out
