"""SLO objectives and multi-window burn-rate monitors.

An ``SLObjective`` states a target fraction of *good* events (e.g. "99%
of ticks complete within 8 simulated ticks of latency", "99.5% of
admissions are not blocked"). An ``SLOMonitor`` watches a cumulative
``(bad, total)`` counter pair and computes the **burn rate** over two
windows:

    burn = (bad / total) / (1 - target)

A burn of 1.0 consumes the error budget exactly at the sustainable pace;
a burn of 2.0 exhausts it in half the period. Following the multi-window
pattern (Google SRE workbook), the alert *fires* only when BOTH a short
window (fast reaction) and a long window (sustained evidence, not a
blip) exceed ``fire_burn``, and *clears* only when both drop below
``clear_burn`` — the fire/clear gap is the hysteresis that keeps a burn
hovering near threshold from flapping the alert.

Monitors plug into the autoscale loop: ``FleetController.tick()`` /
``AutoscaleController.tick()`` call ``sample(now)`` and merge the
returned ``slo_<name>_*`` signals into the ``TelemetryBus`` sample, so
scaling policies can target burn rates and alert state exactly like any
other telemetry signal (``Threshold("slo_ttft_firing", hi=0.5)``).

Sources adapt the metrics registry to the ``(bad, total)`` contract:

* ``histogram_threshold_source(hist, threshold)`` — bad = observations
  in buckets at or above ``threshold``;
* ``counter_ratio_source(bad, total)`` — e.g. admission blocks over
  admission attempts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

from repro.obs.metrics import Counter, Histogram

__all__ = [
    "SLObjective", "SLOMonitor",
    "histogram_threshold_source", "counter_ratio_source",
]

# (time, bad_cum, total_cum)
_Sample = Tuple[float, float, float]


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """A good-fraction target: ``target`` of all events should be good."""
    name: str
    target: float                       # e.g. 0.99 -> 1% error budget
    description: str = ""

    def __post_init__(self) -> None:
        if not 0.0 < self.target < 1.0:
            raise ValueError(
                f"SLO target must be in (0, 1), got {self.target} "
                f"for {self.name!r}")

    @property
    def error_budget(self) -> float:
        return 1.0 - self.target


class SLOMonitor:
    """Multi-window burn-rate alert over a cumulative (bad, total) source.

    ``source()`` must return monotonically non-decreasing cumulative
    counts; the monitor differentiates them over the short and long
    windows itself. Windows are in the same time unit as the ``t``
    passed to ``sample`` (controller ticks by default).
    """

    def __init__(self, slo: SLObjective,
                 source: Callable[[], Tuple[float, float]], *,
                 short_window: float = 20.0, long_window: float = 100.0,
                 fire_burn: float = 2.0, clear_burn: float = 1.0) -> None:
        if short_window <= 0 or long_window < short_window:
            raise ValueError(
                f"need 0 < short_window <= long_window, got "
                f"{short_window}/{long_window}")
        if clear_burn > fire_burn:
            raise ValueError(
                f"clear_burn {clear_burn} must not exceed fire_burn "
                f"{fire_burn} (the gap is the hysteresis)")
        self.slo = slo
        self.source = source
        self.short_window = float(short_window)
        self.long_window = float(long_window)
        self.fire_burn = float(fire_burn)
        self.clear_burn = float(clear_burn)
        self.firing = False
        self.transitions: List[Dict[str, Any]] = []
        self._samples: List[_Sample] = []

    def _burn(self, now: float, window: float) -> float:
        """Burn rate over [now - window, now] from the cumulative samples."""
        if not self._samples:
            return 0.0
        lo = now - window
        # oldest sample still inside the window; fall back to the earliest
        # so startup (short history) uses what it has
        base = self._samples[0]
        for s in self._samples:
            if s[0] >= lo:
                base = s
                break
        t1, bad1, total1 = self._samples[-1]
        _, bad0, total0 = base
        d_total = total1 - total0
        if d_total <= 0:
            return 0.0
        bad_frac = (bad1 - bad0) / d_total
        return bad_frac / self.slo.error_budget

    def sample(self, now: float) -> Dict[str, float]:
        """Pull the source, update alert state, return bus signals."""
        bad, total = self.source()
        self._samples.append((float(now), float(bad), float(total)))
        # keep just enough history to cover the long window
        lo = now - self.long_window
        while len(self._samples) > 2 and self._samples[1][0] <= lo:
            self._samples.pop(0)

        short = self._burn(now, self.short_window)
        long_ = self._burn(now, self.long_window)
        if not self.firing and short > self.fire_burn and long_ > self.fire_burn:
            self.firing = True
            self.transitions.append({"t": now, "to": "firing",
                                     "short": short, "long": long_})
        elif self.firing and short < self.clear_burn and long_ < self.clear_burn:
            self.firing = False
            self.transitions.append({"t": now, "to": "clear",
                                     "short": short, "long": long_})
        n = self.slo.name
        return {f"slo_{n}_burn_short": short,
                f"slo_{n}_burn_long": long_,
                f"slo_{n}_firing": 1.0 if self.firing else 0.0}


def histogram_threshold_source(hist: Histogram,
                               threshold: float) -> Callable[[], Tuple[float, float]]:
    """(bad, total) from a histogram: bad = observations that landed in a
    bucket whose *lower* bound is at or above ``threshold`` — i.e. values
    guaranteed to exceed it. Observations inside the bucket containing
    the threshold count as good (conservative-under: the monitor never
    over-reports badness because of bucket granularity)."""
    bounds = hist.bounds

    def source() -> Tuple[float, float]:
        bad = 0.0
        for i, c in enumerate(hist.counts):
            lower = bounds[i - 1] if i > 0 else 0.0
            if i == len(bounds):        # overflow bucket: above every bound
                lower = bounds[-1]
            if lower >= threshold:
                bad += c
        return bad, float(hist.count)

    return source


def counter_ratio_source(bad: Counter,
                         total: Counter) -> Callable[[], Tuple[float, float]]:
    """(bad, total) straight from two cumulative counters — e.g.
    ``serving_admit_blocked`` over admission attempts."""
    def source() -> Tuple[float, float]:
        return float(bad.value), float(total.value)

    return source
