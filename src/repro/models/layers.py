"""Common layers: RMSNorm, gated MLP, embeddings, logits head."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import ParamSpec


def soft_cap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- RMSNorm ---

def rmsnorm_schema(dim: int) -> ParamSpec:
    return ParamSpec((dim,), (None,), init="ones")


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float,
            plus_one: bool = False) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if plus_one:  # gemma-style (1 + w)
        w = 1.0 + w
    return (y * w).astype(dtype)


# ------------------------------------------------------------------- MLP ---

def mlp_schema(cfg: ModelConfig, d_ff: int,
               ff_axis: str = "ff") -> Dict[str, ParamSpec]:
    d = cfg.d_model
    out = {
        "w_up": ParamSpec((d, d_ff), ("embed", ff_axis)),
        "w_down": ParamSpec((d_ff, d), (ff_axis, "embed")),
    }
    if cfg.mlp_gated:
        out["w_gate"] = ParamSpec((d, d_ff), ("embed", ff_axis))
    return out


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    u = x @ p["w_up"].astype(dt)
    if cfg.mlp_gated:
        u = _act(x @ p["w_gate"].astype(dt), cfg.mlp_act) * u
    else:
        u = _act(u, cfg.mlp_act)
    return u @ p["w_down"].astype(dt)


# ----------------------------------------------------------- Embeddings ---

def embed_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    # vocab-only sharding: FSDP-sharding the d_model dim of a gathered table
    # triggers SPMD "involuntary full rematerialization" (replicates the
    # gather output); vocab-sharded gathers partition cleanly (mask+psum).
    out = {"tok": ParamSpec((cfg.padded_vocab, cfg.d_model),
                            ("vocab", None), init="embed")}
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                   (None, "vocab"), init="embed")
    return out


def embed(cfg: ModelConfig, p: Dict[str, Any], tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(p["tok"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def logits(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, D) -> (B, S, padded_vocab) float32 with final softcap."""
    w = p["unembed"] if not cfg.tie_embeddings else p["tok"].T
    out = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    return soft_cap(out, cfg.final_softcap)
