"""Elastic autoscaling control plane.

Closes the loop between the serving engine and the cluster control plane:

* ``metrics``    — telemetry bus aggregating per-tick scheduler + heartbeat
                   signals into windowed series on the SimCloud clock;
* ``policy``     — target-tracking and step-scaling policies with
                   hysteresis/cooldown, emitting typed ``ScaleDecision``s;
* ``controller`` — the actuator: live slot/page-pool resize on the paged
                   scheduler, node add/remove through ``ClusterLifecycle``,
                   spot-preemption replacement from the warm-spare pool.

See docs/autoscaling.md for the control-loop walk-through.
"""
from repro.autoscale.controller import AutoscaleController, CapacityBands
from repro.autoscale.metrics import TelemetryBus, sample_scheduler
from repro.autoscale.policy import (ScaleDecision, StepScalingPolicy,
                                    TargetTrackingPolicy)

__all__ = [
    "AutoscaleController", "CapacityBands", "TelemetryBus",
    "sample_scheduler", "ScaleDecision", "StepScalingPolicy",
    "TargetTrackingPolicy",
]
