"""Service interaction — the Hue analogue (paper use cases 5-8).

One client object that fronts every installed service: browse the cluster
store (5), submit compute jobs (6), upload files (7), and run the classic
MapReduce WordCount (8) — implemented here as an actual scatter/map/reduce
over the cluster's logical workers using jnp segment sums, because this
framework's "MapReduce" substrate is JAX.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.provisioner import Cluster
from repro.core.services import PORTS, AmbariServer, ServiceState


class InteractionError(RuntimeError):
    pass


@dataclasses.dataclass
class Job:
    job_id: int
    kind: str
    status: str
    result: Any = None


class InteractionHub:
    """The "Hue" of the system: requires its backing services to be up."""

    def __init__(self, ambari: AmbariServer):
        self.ambari = ambari
        self.cluster: Cluster = ambari.cluster
        self.port = PORTS["hue"]
        self.storage: Dict[str, bytes] = {}
        self.jobs: List[Job] = []

    # ------------------------------------------------------------ plumbing --
    def _require(self, service: str) -> None:
        svc = self.ambari.services.get(service)
        if svc is None or svc.state != ServiceState.STARTED:
            raise InteractionError(
                f"service {service!r} is not running; install+start it "
                f"through the provisioning server first")

    # ------------------------------------------------- use case 5: browse --
    def browse_storage(self, prefix: str = "") -> List[Dict[str, Any]]:
        self._require("hdfs")
        return [{"path": k, "bytes": len(v)}
                for k, v in sorted(self.storage.items())
                if k.startswith(prefix)]

    # ------------------------------------------------- use case 7: upload --
    def upload_file(self, path: str, data: bytes) -> Dict[str, Any]:
        self._require("hdfs")
        self.storage[path] = data
        # block placement across slaves (HDFS-analogue)
        slaves = self.cluster.directory.slaves()
        replicas = self.ambari.services["hdfs"].config.get(
            "replicas", len(slaves))
        placement = [s.hostname for s in slaves[:max(1, replicas)]]
        self.cluster.log.emit(self.ambari.cloud.clock, "hue", "upload_file",
                              path=path, bytes=len(data),
                              placement=placement)
        return {"path": path, "bytes": len(data), "placement": placement}

    # ------------------------------------------------- use case 6: submit --
    def submit_job(self, kind: str, fn: Callable[[], Any]) -> Job:
        self._require("spark")
        job = Job(job_id=len(self.jobs), kind=kind, status="running")
        self.jobs.append(job)
        self.cluster.log.emit(self.ambari.cloud.clock, "hue", "submit_job",
                              kind=kind, job_id=job.job_id,
                              driver_port=PORTS["spark-driver"])
        try:
            job.result = fn()
            job.status = "succeeded"
        except Exception as e:  # noqa: BLE001 - surfaced via job status
            job.status = f"failed: {e}"
        return job

    # ---------------------------------------------- use case 8: wordcount --
    def run_wordcount(self, path: str) -> Dict[str, int]:
        """MapReduce WordCount over an uploaded file, executed as an actual
        scatter -> map -> segment-reduce across the cluster's logical
        workers (in JAX, the substrate this framework provisions)."""
        self._require("spark")
        self._require("hdfs")
        if path not in self.storage:
            raise InteractionError(f"no such file {path}")
        words = re.findall(r"[a-z']+", self.storage[path].decode().lower())
        if not words:
            return {}
        vocab = sorted(set(words))
        w2i = {w: i for i, w in enumerate(vocab)}
        ids = np.array([w2i[w] for w in words], np.int32)
        n_workers = max(1, len(self.cluster.directory.slaves()))
        # scatter: pad + split word stream across workers (map phase)
        pad = (-len(ids)) % n_workers
        ids_p = np.concatenate([ids, np.full((pad,), -1, np.int32)])
        shards = ids_p.reshape(n_workers, -1)

        def mapper(shard):  # per-worker partial counts
            ok = shard >= 0
            return jnp.zeros((len(vocab),), jnp.int32).at[
                jnp.where(ok, shard, 0)].add(ok.astype(jnp.int32))

        partials = jax.vmap(mapper)(jnp.asarray(shards))
        counts = jnp.sum(partials, axis=0)        # reduce phase
        result = {w: int(counts[i]) for w, i in w2i.items()}
        self.cluster.log.emit(self.ambari.cloud.clock, "hue", "wordcount",
                              path=path, words=len(words),
                              distinct=len(vocab), workers=n_workers)
        return result

    # ------------------------------------------------------------ metrics --
    def service_pages(self) -> Dict[str, int]:
        """Every started service reachable through one interface (Hue's
        pitch) — name -> port."""
        out = {"hue": self.port, "ambari": self.ambari.port}
        for name, svc in self.ambari.services.items():
            if svc.state == ServiceState.STARTED and svc.port:
                out[name] = svc.port
        return out
