"""Per-architecture smoke tests (deliverable f): reduced config of each
family, one forward/train step on CPU, output shapes + no NaNs; plus full
configs' parameter counts vs published sizes and cell accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, cell_is_runnable
from repro.configs.registry import (ARCHS, EXPECTED_PARAMS_B, REDUCED,
                                    all_cells, get_arch, get_shape)
from repro.models import model as M
from repro.optim.adamw import OptimConfig
from repro.serving import engine as E
from repro.train.steps import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.rope_variant == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            KEY, (B, cfg.enc_positions, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(REDUCED))
def test_reduced_forward_and_loss(name):
    cfg = REDUCED[name]
    params = M.init(cfg, KEY)
    loss, metrics = M.loss_fn(cfg, params, _batch(cfg))
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0


@pytest.mark.parametrize("name", sorted(REDUCED))
def test_reduced_train_step(name):
    cfg = REDUCED[name]
    state = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, OptimConfig(warmup_steps=1,
                                                    total_steps=10)))
    new_state, metrics = step(state, _batch(cfg))
    assert int(new_state["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("name", sorted(REDUCED))
def test_reduced_prefill_decode(name):
    cfg = REDUCED[name]
    params = M.init(cfg, KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    del batch["labels"]
    if "positions" in batch:
        batch["positions"] = batch["positions"][:, :, :S]
    lg, cache, cur = E.prefill(cfg, params, batch, capacity=S + 4)
    assert lg.shape == (B, 1, cfg.padded_vocab)
    tok = jnp.argmax(lg[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    lg2, cache = E.decode_step(cfg, params, cache, tok, cur)
    assert lg2.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


def test_greedy_decode_runs():
    cfg = REDUCED["gemma2-2b"]
    params = M.init(cfg, KEY)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    lg, cache, cur = E.prefill(cfg, params, batch, capacity=S + 8)
    first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    toks, cache, cur = E.greedy_decode(cfg, params, cache, first, cur, 5)
    assert toks.shape == (B, 5)
    assert (np.asarray(toks) >= 0).all()
    assert (np.asarray(toks) < cfg.vocab_size).all()


# --------------------------------------------------------- full configs --

@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_param_counts(name):
    cfg = ARCHS[name]
    lo, hi = EXPECTED_PARAMS_B[name]
    pc = cfg.param_count() / 1e9
    assert lo <= pc <= hi, f"{name}: {pc:.2f}B outside [{lo},{hi}]"


def test_cell_grid_is_40_with_7_long_context_skips():
    cells = list(all_cells())
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok in cells if not ok]
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = {a for a, s, ok in cells if s == "long_500k" and ok}
    assert runnable_long == {"mamba2-1.3b", "jamba-v0.1-52b", "gemma2-2b"}


def test_full_schema_abstract_shapes():
    """Full (non-reduced) schemas build abstract params without allocation."""
    from repro.launch.mesh import make_mesh_for
    from repro.models.schema import abstract_params, param_count
    for name in ("qwen1.5-110b", "deepseek-v2-236b"):
        cfg = ARCHS[name]
        sch = M.schema(cfg)
        n = param_count(sch)
        assert abs(n - cfg.param_count()) / cfg.param_count() < 0.02, name


@pytest.mark.parametrize("name", ["jamba-v0.1-52b", "gemma2-2b",
                                  "deepseek-v2-236b"])
def test_depth_plan_covers_all_layers(name):
    from repro.models.transformer import depth_plan
    cfg = ARCHS[name]
    prefix, period, n_periods = depth_plan(cfg)
    assert prefix + period * n_periods == cfg.n_layers
    # kinds at scanned positions are period-invariant
    for p in range(period):
        kinds = {cfg.block_kind(prefix + c * period + p)
                 for c in range(n_periods)}
        moes = {cfg.is_moe_layer(prefix + c * period + p)
                for c in range(n_periods)}
        assert len(kinds) == 1 and len(moes) == 1
