"""Config dataclasses shared by every architecture.

A single ``ModelConfig`` covers all 10 assigned families (dense / moe / ssm /
hybrid / encdec / vlm); per-arch files in ``repro/configs/`` fill it in with
the exact published hyper-parameters. ``ShapeConfig`` describes the assigned
input-shape grid.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- norms / embeddings ------------------------------------------------
    rms_eps: float = 1e-6
    use_post_norm: bool = False      # gemma2: extra norm after attn/mlp
    tie_embeddings: bool = True
    scale_embed: bool = False        # gemma2: embed * sqrt(d_model)
    mlp_act: str = "silu"            # silu | gelu
    mlp_gated: bool = True           # whisper: plain 2-matrix MLP

    # --- attention ----------------------------------------------------------
    attn_impl: str = "gqa"           # gqa | mla | none
    rope_variant: str = "full"       # full | half2d | mrope | none | abs
    rope_theta: float = 10000.0
    qk_norm: bool = False            # qwen3: per-head RMS on q and k
    qkv_bias: bool = False           # qwen1.5 / chatglm3
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    # period pattern of block kinds, tiled over depth.
    #   "attn" | "attn_local" | "ssm"
    layer_pattern: Tuple[str, ...] = ("attn",)

    # --- MLA (deepseek-v2) ---------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    expert_d_ff: int = 0
    shared_expert_d_ff: int = 0
    moe_period: int = 1              # layer i is MoE iff i % period == period-1
    first_k_dense: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    norm_topk_prob: bool = False
    shared_expert_gate: bool = False  # qwen2-moe sigmoid gate on shared expert

    # --- SSM (mamba2 / jamba) --------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # --- encoder-decoder (whisper) ---------------------------------------------
    n_enc_layers: int = 0            # >0 => enc-dec; n_layers are decoder layers
    enc_positions: int = 1500        # frames after the (stubbed) conv frontend

    # --- numerics ----------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    max_position: int = 1 << 20

    # --- perf levers (§Perf; defaults = paper-faithful baseline) ----------------
    moe_combine: str = "gather"      # gather | scatter (partial-sum + psum)
    cache_quant: Any = False         # KV cache quant (serving): False |
                                     # True/"int8" | "fp8" (float8_e4m3)
    attn_mask_opt: bool = False      # skip masking on interior causal blocks
    mla_shard: str = "lora"          # lora | heads (Megatron column-parallel
                                     # up-projections: no per-layer AR)

    # ---------------------------------------------------------------- derived ---
    @property
    def resolved_head_dim(self) -> int:
        if self.attn_impl == "mla":
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        # pad so the vocab dim shards cleanly on a 16/32-wide model axis
        return _round_up(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def block_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.n_routed_experts <= 0 or layer_idx < self.first_k_dense:
            return False
        return layer_idx % self.moe_period == self.moe_period - 1

    # parameter-count estimate (embedding + blocks), for config sanity tests
    def param_count(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        total = self.padded_vocab * d  # embed (tied head adds nothing)
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        def attn_params() -> int:
            if self.attn_impl == "mla":
                qin = self.q_lora_rank or d
                p = 0
                if self.q_lora_rank:
                    p += d * self.q_lora_rank
                p += qin * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                p += self.n_heads * self.v_head_dim * d
                return p
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o
        def dense_mlp(ff: int) -> int:
            return (3 if self.mlp_gated else 2) * d * ff
        def ssm_params() -> int:
            di, n, g = self.ssm_d_inner, self.ssm_state, self.ssm_ngroups
            proj_in = d * (2 * di + 2 * g * n + self.ssm_nheads)
            conv = (di + 2 * g * n) * self.ssm_conv
            return proj_in + conv + di * d + 2 * self.ssm_nheads + di
        n_total_layers = self.n_layers + self.n_enc_layers
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            total += ssm_params() if kind == "ssm" else attn_params()
            if self.is_moe_layer(i):
                total += self.n_routed_experts * dense_mlp(self.expert_d_ff)
                total += d * self.n_routed_experts  # router
                if self.n_shared_experts:
                    total += dense_mlp(self.shared_expert_d_ff
                                       or self.n_shared_experts * self.expert_d_ff)
            else:
                total += dense_mlp(self.d_ff)
        for _ in range(self.n_enc_layers):
            total += attn_params() + dense_mlp(self.d_ff)
            total += attn_params()  # decoder cross-attn (paired per enc layer here)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k only)."""
        if self.n_routed_experts <= 0:
            return self.param_count()
        d = self.d_model
        dense_moe = 3 * d * self.expert_d_ff
        inactive = (self.n_routed_experts - self.moe_top_k) * dense_moe
        n_moe = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        return self.param_count() - n_moe * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs whose attention is sub-quadratic enough for 500k decode
LONG_CONTEXT_OK = ("mamba2-1.3b", "jamba-v0.1-52b", "gemma2-2b")


def cell_is_runnable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in LONG_CONTEXT_OK
    return True
