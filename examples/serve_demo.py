"""Serving demo: static-batch engine vs continuous batching with paged KV.

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch gemma2-2b]
(uses the arch's REDUCED config so it runs on CPU; the full configs are
exercised by the dry-run).

Part 1 drives the original fixed-batch engine (``repro.serving.engine``).
Part 2 serves the same prompts through the continuous-batching scheduler
(``repro.serving.scheduler``): requests arrive staggered, join a free
decode slot, and free their pages when done — watch ``decode_steps`` stay
close to (total tokens / slots) even though lengths are mixed.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.serving import engine as E
from repro.serving.scheduler import ContinuousBatchingScheduler, supports_paged


def static_demo(cfg, params, args) -> None:
    key = jax.random.PRNGKey(0)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.rope_variant == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_positions, cfg.d_model), jnp.float32)

    t0 = time.time()
    lg, cache, cur = E.prefill(cfg, params, batch,
                               capacity=S + args.gen + 8)
    lg.block_until_ready()
    t_prefill = time.time() - t0
    print(f"[static] prefill {B}x{S} in {t_prefill*1e3:.0f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(
        jnp.int32)[:, None]
    t0 = time.time()
    toks, cache, cur = E.greedy_decode(cfg, params, cache, first, cur,
                                       args.gen)
    toks.block_until_ready()
    t_dec = time.time() - t0
    print(f"[static] decode {args.gen} steps x {B} streams in "
          f"{t_dec*1e3:.0f} ms ({B*args.gen/t_dec:.1f} tok/s)")
    print("[static] sampled token ids (stream 0):",
          list(map(int, toks[0][:16])))


def paged_demo(cfg, params, args) -> None:
    rng = np.random.RandomState(0)
    sched = ContinuousBatchingScheduler(
        cfg, params, max_slots=args.batch, page_size=8,
        max_seq_len=args.prompt_len + args.gen + 8)
    n_req = 2 * args.batch
    for i in range(n_req):
        plen = int(rng.randint(max(args.prompt_len // 2, 1),
                               args.prompt_len + 1))
        gen = int(rng.randint(max(args.gen // 4, 1), args.gen + 1))
        sched.submit(rng.randint(0, cfg.vocab_size, size=plen), gen,
                     arrival_step=i // 2)          # staggered arrivals
    t0 = time.time()
    done = sched.run()
    wall = time.time() - t0
    s = sched.stats
    print(f"[paged]  {len(done)} mixed-length requests on {args.batch} "
          f"slots: {s['tokens_out']} tokens in {s['decode_steps']} decode "
          f"steps ({s['tokens_out']/wall:.1f} tok/s, peak "
          f"{s['peak_pages']} pages)")
    print(f"[paged]  request {done[0].rid} (first to finish) token ids:",
          done[0].out_tokens[:16])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(REDUCED))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = REDUCED[args.arch]
    params = M.init(cfg, jax.random.PRNGKey(0))
    static_demo(cfg, params, args)
    if supports_paged(cfg):
        paged_demo(cfg, params, args)
    else:
        print(f"[paged]  skipped: {cfg.name} (MLA/enc-dec) uses the dense "
              "engine")


if __name__ == "__main__":
    main()
