"""PageAllocator live-resize + refcount invariants (hypothesis stateful).

The allocator is the serving engine's memory-safety keystone: admission
reservations, live grow, drain-before-shrink, and now prefix sharing all
assume that at every point in *any* operation sequence the page-id space
partitions cleanly into {free} ∪ {allocated (ref > 0)} ∪
{retired-by-pending-shrink} with the sink page in none of them. These
properties drive random interleavings of alloc / share / free / COW-fork /
grow / request_shrink / complete_shrink and check, after every step:

* the partition (free + allocated + retired == pool size − sink);
* a page with live sharers (ref > 0) is never on the free list and is
  never reclaimed by a shrink;
* a COW fork conserves ``num_free + num_allocated`` (the fork allocates
  one page and drops one reference — pool accounting must not leak);
* duplicate page ids in one ``free`` call always raise, mutating nothing.

The state-machine analogue of the hand-written sequences in
tests/test_autoscale.py and tests/test_prefix_cache.py.

``ShardedPoolMachine`` adds the shard-group rule set (PR 5): a tp-way
group keeps ONE logical allocator over tp per-shard storage planes
(``repro.serving.paged_cache`` — pages are logical, storage is per
shard). The machine drives alloc/share/COW-fork/free through the single
control plane while maintaining each shard's storage plane explicitly,
and asserts after every step that per-shard free/allocated counts stay
equal across shards and that an atomic COW (``copy_page`` copies every
shard's slice in one call) leaves no shard holding stale page contents.

``TieredPoolMachine`` adds the host-RAM page tier rule set (PR 10): one
device ``PageAllocator`` + ``PrefixIndex`` and one ``HostPageTier``
exchange whole page chains through the scheduler's swap order (store
rows → ``swap_chain`` → free the source tier). Random interleavings of
admit / share / swap-out / swap-in / drop check, after every step, that
every live page is resident in exactly one tier, that a swap conserves
refcounts, stored bytes, and each pool's free+allocated partition, and
that no live prefix-index entry ever mixes device and host page ids —
the index never points at a half-swapped chain.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                 precondition, rule)

from repro.serving.paged_cache import (SINK_PAGE, HostPageTier, PageAllocator,
                                       PrefixIndex, as_host_page,
                                       host_page_id, is_host_page,
                                       pages_for_len)


class AllocatorMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.alloc = PageAllocator(8)
        self.refs = {}                     # page -> refcount (shadow model)
        self.next_owner = 0

    # ------------------------------------------------------------- rules --
    @rule(n=st.integers(min_value=1, max_value=6))
    def alloc_pages(self, n):
        if self.alloc.can_alloc(n):
            pages = self.alloc.alloc(n, owner=self.next_owner)
            assert len(set(pages)) == n, "duplicate page in one alloc"
            assert SINK_PAGE not in pages, "sink page handed out"
            for p in pages:
                assert p not in self.refs, f"page {p} double-allocated"
                self.refs[p] = 1
            self.next_owner += 1
        else:
            with pytest.raises(MemoryError):
                self.alloc.alloc(n)

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def share_pages(self, data):
        pages = data.draw(st.lists(st.sampled_from(sorted(self.refs)),
                                   min_size=1, unique=True), label="share")
        self.alloc.share(pages)
        for p in pages:
            self.refs[p] += 1

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def free_pages(self, data):
        pages = data.draw(st.lists(st.sampled_from(sorted(self.refs)),
                                   min_size=1, unique=True), label="free")
        self.alloc.free(pages)
        for p in pages:
            self.refs[p] -= 1
            if not self.refs[p]:
                del self.refs[p]

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def duplicate_free_raises(self, data):
        p = data.draw(st.sampled_from(sorted(self.refs)), label="dup")
        before = (self.alloc.num_free, self.alloc.num_allocated,
                  self.alloc.ref(p))
        with pytest.raises(ValueError):
            self.alloc.free([p, p])
        after = (self.alloc.num_free, self.alloc.num_allocated,
                 self.alloc.ref(p))
        assert before == after, "raising free() must not mutate"

    @precondition(lambda self: any(r >= 2 for r in self.refs.values()))
    @rule(data=st.data())
    def cow_fork(self, data):
        """Fork a shared page: alloc the copy, drop one ref on the source.
        ``num_free + num_allocated`` must be conserved."""
        if not self.alloc.can_alloc(1):
            return
        src = data.draw(st.sampled_from(
            sorted(p for p, r in self.refs.items() if r >= 2)), label="src")
        total = self.alloc.num_free + self.alloc.num_allocated
        dst = self.alloc.alloc(1, owner=self.next_owner)[0]
        self.next_owner += 1
        self.refs[dst] = 1
        self.alloc.free([src])
        self.refs[src] -= 1
        assert self.alloc.num_free + self.alloc.num_allocated == total, \
            "COW fork leaked pool capacity"

    @rule(k=st.integers(min_value=0, max_value=8))
    def grow(self, k):
        self.alloc.grow(self.alloc.num_pages + k)
        assert not self.alloc.shrink_pending   # grow cancels pending shrinks

    @rule(data=st.data())
    def request_shrink(self, data):
        target = data.draw(st.integers(min_value=2,
                                       max_value=self.alloc.num_pages),
                           label="target")
        self.alloc.request_shrink(target)
        assert self.alloc.effective_pages == min(self.alloc.num_pages, target)

    @precondition(lambda self: self.alloc.shrink_ready())
    @rule()
    def complete_shrink(self):
        new = self.alloc.complete_shrink()
        assert new == self.alloc.num_pages
        assert not self.alloc.shrink_pending
        assert all(p < new for p in self.refs), \
            "shrink reclaimed a page with live sharers"

    # -------------------------------------------------------- invariants --
    @invariant()
    def partition_covers_pool(self):
        a = self.alloc
        free = set(a._free)
        allocated = set(a._ref)
        every = set(range(1, a.num_pages))
        retired = every - free - allocated
        # free + used + retired == pool size (sink excluded from all three)
        assert len(free) + len(allocated) + len(retired) == a.num_pages - 1
        assert len(a._free) == len(free), "duplicate ids on the free list"
        assert not (free & allocated), "page both free and referenced"
        assert SINK_PAGE not in free and SINK_PAGE not in allocated
        # retired pages exist only under a pending shrink, above its target
        if retired:
            assert a.shrink_pending
            assert all(p >= a._shrink_target for p in retired)
        # free pages below a pending shrink target only
        if a.shrink_pending:
            assert all(p < a._shrink_target for p in free)

    @invariant()
    def shadow_model_agrees(self):
        assert dict(self.alloc._ref) == self.refs
        assert self.alloc.num_allocated == len(self.refs)
        assert all(r > 0 for r in self.refs.values())
        assert self.alloc.capacity >= 0

    @invariant()
    def shrink_blocked_by_sharers(self):
        if self.alloc.shrink_ready():
            assert all(p < self.alloc._shrink_target for p in self.refs)


TestAllocatorProps = AllocatorMachine.TestCase
TestAllocatorProps.settings = settings(max_examples=60,
                                       stateful_step_count=40,
                                       deadline=None)


class ShardedPoolMachine(RuleBasedStateMachine):
    """One logical allocator, ``TP`` per-shard storage planes.

    Mirrors the scheduler's shard-group contract: every control-plane op
    (alloc / share / free / COW fork) applies to all shards at once —
    alloc stamps the page's slice in every shard, the COW fork copies the
    source page's slice in every shard (``paged_cache.copy_page`` with a
    leading shard axis) — so the planes can never skew.
    """

    TP = 2

    def __init__(self):
        super().__init__()
        self.alloc = PageAllocator(8)
        self.refs = {}                      # page -> refcount (shadow)
        # per-shard storage planes: page -> content stamp; the stamp a
        # shard holds for page p models its kv-head slice of p
        self.planes = [dict() for _ in range(self.TP)]
        self.stamp = 0

    def _write_all(self, page):
        """A prefill insert: every shard's slice written in one call."""
        self.stamp += 1
        for plane in self.planes:
            plane[page] = self.stamp

    # ------------------------------------------------------------- rules --
    @rule(n=st.integers(min_value=1, max_value=4))
    def alloc_pages(self, n):
        if not self.alloc.can_alloc(n):
            return
        pages = self.alloc.alloc(n)
        for p in pages:
            self.refs[p] = 1
            self._write_all(p)

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def share_pages(self, data):
        pages = data.draw(st.lists(st.sampled_from(sorted(self.refs)),
                                   min_size=1, unique=True), label="share")
        self.alloc.share(pages)
        for p in pages:
            self.refs[p] += 1
        # sharing is control-plane only: no shard's storage changes

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def free_pages(self, data):
        pages = data.draw(st.lists(st.sampled_from(sorted(self.refs)),
                                   min_size=1, unique=True), label="free")
        self.alloc.free(pages)
        for p in pages:
            self.refs[p] -= 1
            if not self.refs[p]:
                del self.refs[p]
                for plane in self.planes:   # last owner: slice recycled
                    del plane[p]

    @precondition(lambda self: any(r >= 2 for r in self.refs.values()))
    @rule(data=st.data())
    def cow_fork(self, data):
        """Diverge inside a shared page: alloc the copy, copy *every*
        shard's slice atomically, drop one ref on the source."""
        if not self.alloc.can_alloc(1):
            return
        src = data.draw(st.sampled_from(
            sorted(p for p, r in self.refs.items() if r >= 2)), label="src")
        dst = self.alloc.alloc(1)[0]
        self.refs[dst] = 1
        for plane in self.planes:           # the atomic whole-group copy
            plane[dst] = plane[src]
        self.alloc.free([src])
        self.refs[src] -= 1

    # -------------------------------------------------------- invariants --
    @invariant()
    def per_shard_counts_stay_equal(self):
        """The satellite's acceptance: after any alloc/share/COW/free
        sequence, every shard holds slices for exactly the allocated
        logical pages — per-shard free/allocated counts are equal."""
        allocated = set(self.alloc._ref)
        for s, plane in enumerate(self.planes):
            assert set(plane) == allocated, f"shard {s} skewed"
        counts = {(self.alloc.num_pages - 1 - len(plane), len(plane))
                  for plane in self.planes}
        assert len(counts) == 1, "per-shard free/allocated counts diverged"

    @invariant()
    def cow_left_no_stale_shard(self):
        """Any two shards agree on every page's contents (same stamp) —
        a non-atomic COW would break this on the first fork."""
        for plane in self.planes[1:]:
            assert plane == self.planes[0]

    @invariant()
    def control_plane_agrees(self):
        assert dict(self.alloc._ref) == self.refs


TestShardedPoolProps = ShardedPoolMachine.TestCase
TestShardedPoolProps.settings = settings(max_examples=50,
                                         stateful_step_count=40,
                                         deadline=None)


class MigrationMachine(RuleBasedStateMachine):
    """Page migration between two pools (PR 6's disaggregation handoff).

    Two independent allocator+prefix-index pairs model a prefill-role and a
    decode-role replica. Streams admit on the prefill pool (optionally
    sharing an earlier stream's pages, the prefix-hit path), migrate —
    alloc on the decode side, free on the prefill side, exactly the
    adopt-then-surrender order the scheduler uses — and finish wherever
    they live. After every step:

    * refcounts are conserved per pool: each allocator's ledger equals the
      refs implied by the streams currently resident in that pool;
    * a stream's pages live in exactly one pool — migration leaves nothing
      behind and nothing half-moved;
    * no live prefix-index entry references a migrated-away (freed) page:
      the ``on_free`` hook must kill the donor's entries the moment the
      handoff releases its pages, or a later admission would share pages
      whose contents left the pool.
    """

    PAGE = 4
    POOL = 16

    def __init__(self):
        super().__init__()
        self.pools = {}
        self.index = {}
        for side in ("prefill", "decode"):
            self.pools[side] = PageAllocator(self.POOL)
            self.index[side] = PrefixIndex(self.PAGE)
            self.pools[side].on_free = self.index[side].invalidate_page
        self.streams = {}     # sid -> {"side", "pages", "prompt"}
        self.refs = {"prefill": {}, "decode": {}}   # shadow ledgers
        self.sid = 0

    def _new_prompt(self, plen):
        # distinct prompts per stream: accidental index hits would make the
        # shadow ledger ambiguous without buying the rules anything
        p = np.full((plen,), self.sid, np.int32)
        p[::2] = np.arange(0, plen, 2, dtype=np.int32)
        return p

    # ------------------------------------------------------------- rules --
    @rule(plen=st.integers(min_value=4, max_value=20))
    def admit(self, plen):
        alloc = self.pools["prefill"]
        n = pages_for_len(plen + 1, self.PAGE)
        if not alloc.can_alloc(n):
            return
        prompt = self._new_prompt(plen)
        pages = alloc.alloc(n, owner=self.sid)
        self.index["prefill"].insert(prompt, pages)
        self.streams[self.sid] = {"side": "prefill", "pages": pages,
                                  "prompt": prompt}
        for p in pages:
            self.refs["prefill"][p] = self.refs["prefill"].get(p, 0) + 1
        self.sid += 1

    @precondition(lambda self: any(s["side"] == "prefill"
                                   for s in self.streams.values()))
    @rule(data=st.data())
    def admit_shared(self, data):
        """A prefix hit on the prefill side: the new stream shares an
        earlier resident's pages (refcount++), no fresh allocation."""
        donors = sorted(k for k, s in self.streams.items()
                        if s["side"] == "prefill")
        donor = self.streams[data.draw(st.sampled_from(donors),
                                       label="donor")]
        pages = list(donor["pages"])
        self.pools["prefill"].share(pages)
        self.streams[self.sid] = {"side": "prefill", "pages": pages,
                                  "prompt": donor["prompt"]}
        for p in pages:
            self.refs["prefill"][p] += 1
        self.sid += 1

    @precondition(lambda self: any(s["side"] == "prefill"
                                   for s in self.streams.values()))
    @rule(data=st.data())
    def migrate(self, data):
        """Handoff: adopt (alloc + index on the decode side) before
        surrender (free on the prefill side) — the scheduler's order, so
        the pages being copied can never be recycled mid-copy."""
        sids = sorted(k for k, s in self.streams.items()
                      if s["side"] == "prefill")
        sid = data.draw(st.sampled_from(sids), label="migrate")
        stream = self.streams[sid]
        src = stream["pages"]
        if not self.pools["decode"].can_alloc(len(src)):
            return
        dst = self.pools["decode"].alloc(len(src), owner=sid)
        self.index["decode"].insert(stream["prompt"], dst)
        for p in dst:
            self.refs["decode"][p] = self.refs["decode"].get(p, 0) + 1
        self.pools["prefill"].free(src)
        for p in src:
            self.refs["prefill"][p] -= 1
            if not self.refs["prefill"][p]:
                del self.refs["prefill"][p]
        stream["side"], stream["pages"] = "decode", dst

    @precondition(lambda self: any(s["side"] == "prefill"
                                   for s in self.streams.values()))
    @rule(data=st.data())
    def fail_during_handoff(self, data):
        """The donor dies between the adopt copy and the surrender (the
        fail_replica x in-flight migration window): its fail sweep frees
        the source pages exactly once, the guarded surrender then sees a
        cleared slot and must not free again — probe that a second free
        of the recycled pages raises without mutating either ledger — and
        the stream survives wholly decode-resident (never requeued)."""
        sids = sorted(k for k, s in self.streams.items()
                      if s["side"] == "prefill")
        sid = data.draw(st.sampled_from(sids), label="fail-mid-handoff")
        stream = self.streams[sid]
        src = stream["pages"]
        if not self.pools["decode"].can_alloc(len(src)):
            return
        dst = self.pools["decode"].alloc(len(src), owner=sid)
        self.index["decode"].insert(stream["prompt"], dst)
        for p in dst:
            self.refs["decode"][p] = self.refs["decode"].get(p, 0) + 1
        self.pools["prefill"].free(src)      # the donor's fail sweep
        for p in src:
            self.refs["prefill"][p] -= 1
            if not self.refs["prefill"][p]:
                del self.refs["prefill"][p]
        recycled = [p for p in src if self.pools["prefill"].ref(p) == 0]
        if recycled:
            before = (self.pools["prefill"].num_free,
                      self.pools["prefill"].num_allocated)
            with pytest.raises(ValueError):
                self.pools["prefill"].free(recycled)
            after = (self.pools["prefill"].num_free,
                     self.pools["prefill"].num_allocated)
            assert before == after, "raising double-free mutated the pool"
        stream["side"], stream["pages"] = "decode", dst

    @precondition(lambda self: self.streams)
    @rule(data=st.data())
    def finish(self, data):
        sid = data.draw(st.sampled_from(sorted(self.streams)),
                        label="finish")
        stream = self.streams.pop(sid)
        side = stream["side"]
        self.pools[side].free(stream["pages"])
        for p in stream["pages"]:
            self.refs[side][p] -= 1
            if not self.refs[side][p]:
                del self.refs[side][p]

    # -------------------------------------------------------- invariants --
    @invariant()
    def refcounts_conserved(self):
        for side in ("prefill", "decode"):
            assert dict(self.pools[side]._ref) == self.refs[side], \
                f"{side} pool ledger drifted from resident streams"

    @invariant()
    def one_pool_per_stream(self):
        """A stream is wholly resident in one pool: every page it holds is
        live there, and the total pages the two ledgers carry equal the
        pages reachable from streams — nothing orphaned by a migration."""
        for sid, s in self.streams.items():
            alloc = self.pools[s["side"]]
            for p in s["pages"]:
                assert alloc.ref(p) > 0, \
                    f"stream {sid} holds page {p} not live in its pool"
        for side in ("prefill", "decode"):
            reachable = set()
            for s in self.streams.values():
                if s["side"] == side:
                    reachable.update(s["pages"])
            assert set(self.refs[side]) == reachable, \
                f"{side} pool holds pages no resident stream reaches"

    @invariant()
    def index_never_points_at_migrated_pages(self):
        for side in ("prefill", "decode"):
            idx, alloc = self.index[side], self.pools[side]
            for entries in idx._by_page.values():
                for e in entries:
                    if e.dead:
                        continue
                    assert all(alloc.ref(p) > 0 for p in e.pages), \
                        f"live {side} index entry references freed pages"


TestMigrationProps = MigrationMachine.TestCase
TestMigrationProps.settings = settings(max_examples=50,
                                       stateful_step_count=40,
                                       deadline=None)


class TieredPoolMachine(RuleBasedStateMachine):
    """Device tier + host-RAM tier under random swap traffic (PR 10).

    Models the scheduler's preempt-to-host path with numpy stamp rows in
    place of KV pool leaves: each device page carries a unique stamp, a
    swap-out stores that stamp's row in the ``HostPageTier``, and a
    swap-in must read the identical row back — the machine-level version
    of the tier's byte-identity contract. Ordering mirrors
    ``scheduler._evict_chain`` / ``_materialize_hit`` exactly:

    * swap-out: store rows, then ``index.swap_chain`` (re-point entries
      at ``HOST_BIT``-tagged ids), then free the device pages — so the
      ``on_free`` invalidation sweep only kills entries that straddle
      pages another chain still shares (those stayed device-resident);
    * swap-in: alloc fresh device pages, ``swap_chain`` back, and only
      then free the host rows — the host-side ``on_free`` hook must find
      nothing left pointing at the tagged ids.

    The invariants are the tier's safety contract: every live page is
    resident in exactly one tier, both allocators' ledgers match the
    chains that reach them, ``bytes_used`` tracks exactly the resident
    rows, and no live index entry mixes tagged and untagged page ids.
    """

    PAGE = 4
    POOL = 16
    HOST = 12

    def __init__(self):
        super().__init__()
        self.alloc = PageAllocator(self.POOL)
        self.index = PrefixIndex(self.PAGE)
        self.tier = HostPageTier(self.HOST)
        self.alloc.on_free = self.index.invalidate_page
        self.tier.alloc.on_free = (
            lambda p: self.index.invalidate_page(as_host_page(p)))
        # cid -> {"side", "pages", "prompt", "stamps"} (host side swaps
        # "pages"/"stamps" for "host": host_id -> stamp)
        self.chains = {}
        self.refs = {}                     # device shadow ledger
        self.cid = 0
        self.stamp = 0

    def _row(self, stamp):
        return {"k": np.full((self.PAGE,), stamp, np.int32)}

    # ------------------------------------------------------------- rules --
    @rule(plen=st.integers(min_value=4, max_value=14))
    def admit(self, plen):
        n = pages_for_len(plen, self.PAGE)
        if not self.alloc.can_alloc(n):
            return
        # distinct prompts per chain (same reasoning as MigrationMachine)
        prompt = np.full((plen,), self.cid, np.int32)
        prompt[::2] = np.arange(0, plen, 2, dtype=np.int32)
        pages = self.alloc.alloc(n, owner=self.cid)
        self.index.insert(prompt, pages)
        stamps = {}
        for p in pages:
            self.stamp += 1
            stamps[p] = self.stamp
            self.refs[p] = self.refs.get(p, 0) + 1
        self.chains[self.cid] = {"side": "device", "pages": pages,
                                 "prompt": prompt, "stamps": stamps}
        self.cid += 1

    @precondition(lambda self: any(c["side"] == "device"
                                   for c in self.chains.values()))
    @rule(data=st.data())
    def share_chain(self, data):
        """A prefix hit: a second reader shares a *prefix* of a
        device-resident chain, pinning those pages against swap-out
        (ref > 1 pages never move) — so a later swap-out of the donor is
        partial, and entries straddling moved/kept pages must die."""
        donors = sorted(k for k, c in self.chains.items()
                        if c["side"] == "device")
        donor = self.chains[data.draw(st.sampled_from(donors),
                                      label="donor")]
        depth = data.draw(st.integers(min_value=1,
                                      max_value=len(donor["pages"])),
                          label="depth")
        pages = list(donor["pages"][:depth])
        self.alloc.share(pages)
        for p in pages:
            self.refs[p] += 1
        self.chains[self.cid] = {"side": "device", "pages": pages,
                                 "prompt": donor["prompt"][:depth * self.PAGE],
                                 "stamps": {p: donor["stamps"][p]
                                            for p in pages}}
        self.cid += 1

    @precondition(lambda self: any(c["side"] == "device"
                                   for c in self.chains.values()))
    @rule(data=st.data())
    def swap_out(self, data):
        """Preempt a device chain to host: only its last-reference pages
        move (shared prefix pages stay device-resident with the sharer);
        entries straddling moved and kept pages die via ``on_free``."""
        cids = sorted(k for k, c in self.chains.items()
                      if c["side"] == "device")
        ch = self.chains[data.draw(st.sampled_from(cids), label="evict")]
        dying = [p for p in ch["pages"] if self.alloc.ref(p) == 1]
        if not dying or not self.tier.can_hold(len(dying)):
            return
        host = self.tier.alloc.alloc(len(dying))
        for h, p in zip(host, dying):
            self.tier.store(h, self._row(ch["stamps"][p]))
        self.index.swap_chain({p: as_host_page(h)
                               for p, h in zip(dying, host)})
        self.alloc.free(ch["pages"])
        for p in ch["pages"]:
            self.refs[p] -= 1
            if not self.refs[p]:
                del self.refs[p]
        ch["side"] = "host"
        ch["host"] = {h: ch["stamps"][p] for p, h in zip(dying, host)}
        ch["pages"], ch["stamps"] = [], {}

    @precondition(lambda self: any(c["side"] == "host"
                                   for c in self.chains.values()))
    @rule(data=st.data())
    def swap_in(self, data):
        """Resume a host chain: rows must restore byte-exactly into fresh
        device pages, and the index re-points before the rows are freed."""
        cids = sorted(k for k, c in self.chains.items()
                      if c["side"] == "host")
        ch = self.chains[data.draw(st.sampled_from(cids), label="resume")]
        host = sorted(ch["host"])
        if not self.alloc.can_alloc(len(host)):
            return
        dst = self.alloc.alloc(len(host), owner="resume")
        self.index.swap_chain({as_host_page(h): d
                               for h, d in zip(host, dst)})
        stamps = {}
        for h, d in zip(host, dst):
            want = ch["host"][h]
            assert np.array_equal(self.tier.rows(h)["k"],
                                  self._row(want)["k"]), \
                "host tier lost row bytes across the swap"
            stamps[d] = want
        self.tier.free(host)
        for d in dst:
            self.refs[d] = 1
        ch["side"], ch["pages"], ch["stamps"] = "device", list(dst), stamps
        del ch["host"]

    @precondition(lambda self: self.chains)
    @rule(data=st.data())
    def drop_chain(self, data):
        """Finish (device side) or host-tier eviction (host side): the
        chain's pages leave whichever tier holds them, exactly once."""
        cid = data.draw(st.sampled_from(sorted(self.chains)), label="drop")
        ch = self.chains.pop(cid)
        if ch["side"] == "device":
            self.alloc.free(ch["pages"])
            for p in ch["pages"]:
                self.refs[p] -= 1
                if not self.refs[p]:
                    del self.refs[p]
        else:
            self.tier.free(sorted(ch["host"]))

    # -------------------------------------------------------- invariants --
    @invariant()
    def every_live_page_in_exactly_one_tier(self):
        device, host = set(), set()
        for c in self.chains.values():
            if c["side"] == "device":
                device.update(c["pages"])
            else:
                host.update(c["host"])
        assert device == set(self.refs), \
            "device ledger drifted from chain-reachable pages"
        assert dict(self.alloc._ref) == self.refs, \
            "device allocator refcounts drifted from the shadow ledger"
        assert set(self.tier.alloc._ref) == host, \
            "host tier holds pages no chain reaches (or lost live ones)"
        assert self.tier.pages_used == len(host)

    @invariant()
    def swap_conserves_bytes(self):
        per_row = self.PAGE * np.dtype(np.int32).itemsize
        assert self.tier.bytes_used == per_row * self.tier.pages_used, \
            "bytes_used drifted from resident rows"
        assert set(self.tier._rows) == set(self.tier.alloc._ref), \
            "host rows and host allocator disagree on residency"

    @invariant()
    def partition_covers_both_pools(self):
        for name, a in (("device", self.alloc), ("host", self.tier.alloc)):
            free, used = set(a._free), set(a._ref)
            assert not (free & used), f"{name} page both free and used"
            assert len(free) + len(used) == a.num_pages - 1, \
                f"{name} pool partition leaked pages"
            assert SINK_PAGE not in free and SINK_PAGE not in used

    @invariant()
    def index_never_half_swapped(self):
        for entries in self.index._by_page.values():
            for e in entries:
                if e.dead:
                    continue
                tagged = {is_host_page(p) for p in e.pages}
                assert len(tagged) == 1, \
                    "live index entry mixes device and host page ids"
                if tagged == {True}:
                    assert all(self.tier.alloc.ref(host_page_id(p)) > 0
                               for p in e.pages), \
                        "index points at freed host rows"
                else:
                    assert all(self.alloc.ref(p) > 0 for p in e.pages), \
                        "index points at freed device pages"


TestTieredPoolProps = TieredPoolMachine.TestCase
TestTieredPoolProps.settings = settings(max_examples=50,
                                        stateful_step_count=40,
                                        deadline=None)
