"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim 256) d_ff=9216 vocab=256000
[arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    mlp_act="gelu",
    rope_theta=10000.0,
    sliding_window=4096,
    layer_pattern=("attn_local", "attn"),   # even layers sliding-window
    attn_softcap=50.0,
    final_softcap=30.0,
    use_post_norm=True,
    scale_embed=True,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma2-2b-reduced",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    mlp_act="gelu",
    sliding_window=16,
    layer_pattern=("attn_local", "attn"),
    attn_softcap=50.0,
    final_softcap=30.0,
    use_post_norm=True,
    scale_embed=True,
)
