"""Static-batch serving engine: KV/state cache management, prefill + decode.

Cache layout mirrors the model's scan structure (see
``repro.models.model.cache_schema``). Sliding-window layers get
window-capacity ring buffers; SSM layers carry (state, conv-tail). The
decode step is a single jit-able function suitable for pjit lowering in the
dry-run (``decode_32k`` / ``long_500k`` cells).

This engine decodes one fixed batch at a time — every stream pays
``capacity`` cache memory and the batch runs until its longest member
finishes. For mixed-length request traffic use the continuous-batching
scheduler (``repro.serving.scheduler``) over the paged variant of this
cache (``repro.serving.paged_cache``): same quantisation contract
(``quantize_kv``), but K/V live in a shared page pool so sequences join
and leave mid-flight. MLA and enc-dec archs stay on this engine (see
docs/serving.md).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.schema import init_params
from repro.serving.request import Request

_SEQ_LEAVES = {"k", "v", "c_kv", "k_pe", "k_scale", "v_scale"}
_SEQ_AXIS_FROM_END = {"k": 3, "v": 3, "c_kv": 2, "k_pe": 2,
                      "k_scale": 2, "v_scale": 2}


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Any:
    """Zero-initialised cache pytree with ring-buffer capacities."""
    sch = M.cache_schema(cfg, batch, capacity)
    return init_params(sch, jax.random.PRNGKey(0))


def _place_seq(buf: jnp.ndarray, kv: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Place prefill kv (length S) into a capacity-``cap`` ring buffer."""
    cap, S = buf.shape[axis], kv.shape[axis]
    if S >= cap:
        tail = jax.lax.slice_in_dim(kv, S - cap, S, axis=axis)
        pos = (S - cap + np.arange(cap)) % cap
        inv = np.argsort(pos)               # slot j <- tail[inv[j]]
        return jnp.take(tail, inv, axis=axis)
    return jax.lax.dynamic_update_slice_in_dim(buf, kv, 0, axis=axis)


def load_prefill_cache(zeros: Any, pre: Any, path=()) -> Any:
    """Merge prefill-produced cache into the capacity-sized zero cache.

    When the target cache is int8-quantised (``cfg.cache_quant``) the
    prefill's bf16 kv is quantised here and scale leaves are synthesised.
    """
    if isinstance(zeros, dict):
        out = {}
        for k in zeros:
            if k in ("k_scale", "v_scale") and k not in pre:
                from repro.models.attention import quantize_kv
                _, scale = quantize_kv(pre[k[0]])
                out[k] = load_prefill_cache(zeros[k], scale, path + (k,))
            elif k in ("k", "v") and zeros[k].dtype == jnp.int8 \
                    and pre[k].dtype != jnp.int8:
                from repro.models.attention import quantize_kv
                q8, _ = quantize_kv(pre[k])
                out[k] = load_prefill_cache(zeros[k], q8, path + (k,))
            else:
                out[k] = load_prefill_cache(zeros[k], pre[k], path + (k,))
        return out
    key = path[-1]
    if key in _SEQ_LEAVES:
        axis = zeros.ndim - _SEQ_AXIS_FROM_END[key]
        return _place_seq(zeros, pre.astype(zeros.dtype), axis)
    return pre.astype(zeros.dtype)          # ssm h / conv states


def prefill(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            capacity: int):
    """-> (last-token logits, capacity cache, cur_len)."""
    B, S = batch["tokens"].shape
    lg, pre_cache = M.prefill(cfg, params, batch)
    zeros = init_cache(cfg, B, capacity)
    cache = load_prefill_cache(zeros, pre_cache)
    return lg, cache, jnp.asarray(S, jnp.int32)


def decode_step(cfg: ModelConfig, params, cache, tokens: jnp.ndarray,
                cur_len: jnp.ndarray):
    """One serving step: tokens (B,1) at position cur_len."""
    return M.decode_step(cfg, params, cache, tokens, cur_len)


def greedy_decode(cfg: ModelConfig, params, cache, first_token: jnp.ndarray,
                  cur_len: jnp.ndarray, n_steps: int):
    """Greedy generation loop (lax.scan over steps). -> (tokens, cache)."""

    def body(carry, _):
        tok, cl, cc = carry
        lg, cc = M.decode_step(cfg, params, cc, tok, cl)
        nxt = jnp.argmax(lg[:, -1, :cfg.vocab_size], axis=-1
                         ).astype(jnp.int32)[:, None]
        return (nxt, cl + 1, cc), nxt

    (_, cur_len, cache), toks = jax.lax.scan(
        body, (first_token, cur_len, cache), None, length=n_steps)
    return jnp.moveaxis(toks[..., 0], 0, 1), cache, cur_len


# ---------------------------------------------------------------------------
# request-level serving (shared Request lifecycle with the paged scheduler)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "capacity"))
def _jit_prefill(cfg, params, batch, capacity):
    return prefill(cfg, params, batch, capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "n_steps"))
def _jit_decode(cfg, params, cache, first, cur, n_steps):
    return greedy_decode(cfg, params, cache, first, cur, n_steps)


def serve_requests(cfg: ModelConfig, params, requests: List[Request],
                   batch_width: int) -> List[Request]:
    """Serve shared ``Request`` objects the only way a fixed-batch engine
    can: groups of ``batch_width`` in submission order, every prompt padded
    to the group max, decoded until the group's *longest* generation
    finishes. Fills the same ``out_tokens``/``admit_step``/``finish_step``
    bookkeeping the continuous-batching scheduler does, on a virtual clock
    of one tick per decode step (groups are serial, so group n+1's admit
    waits for group n's longest member — the head-of-line blocking being
    measured when this engine is the baseline).

    Caveat: a naive fixed-batch server conditions a short prompt on its
    right padding (the greedy token is read at the group-max position), so
    ``out_tokens`` for padded members reflect that baseline behaviour —
    this is a throughput/latency baseline, not a token oracle; the paged
    scheduler is the token-exact path.
    """
    clock = 0
    for i in range(0, len(requests), batch_width):
        group = requests[i:i + batch_width]
        B = len(group)
        plen = max(r.plen for r in group)
        gen = max(r.max_new_tokens for r in group)
        toks = np.zeros((B, plen), np.int32)
        for j, r in enumerate(group):
            toks[j, :r.plen] = r.prompt
        lg, cache, cur = _jit_prefill(cfg, params,
                                      {"tokens": jnp.asarray(toks)},
                                      plen + gen + 1)
        first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(
            jnp.int32)[:, None]
        out, _, _ = _jit_decode(cfg, params, cache, first, cur, gen - 1)
        out = np.asarray(out)
        for j, r in enumerate(group):
            r.admit_step = clock
            r.out_tokens = ([int(first[j, 0])]
                            + [int(t) for t in out[j]])[:r.max_new_tokens]
            r.finish_step = clock + r.max_new_tokens
            # same hit/miss bookkeeping the paged scheduler fills in: a
            # fixed-batch engine re-prefills every prompt in full, so every
            # request is a miss — keeping the field comparable lets
            # paged-vs-static token-identity checks run on shared-prefix
            # workloads without special-casing the baseline
            r.cached_tokens = 0
        clock += gen                      # group decodes until longest done
    return requests
